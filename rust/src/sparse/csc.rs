//! Compressed Sparse Column matrix: the `X[:,j]` view.
//!
//! Algorithm 2's inner loop is "for all rows i of X with feature j" — that
//! is exactly one CSC column scan (`S_r` entries on average). Built once
//! from the CSR view at dataset load; the two views share nothing so each
//! stays contiguous for its own scan direction.

use super::csr::CsrMatrix;

#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    n_rows: usize,
    n_cols: usize,
    /// Column start offsets, length `n_cols + 1`.
    indptr: Vec<usize>,
    /// Row index of each stored value, length `nnz`.
    indices: Vec<u32>,
    /// Stored values, length `nnz`.
    values: Vec<f32>,
}

impl CscMatrix {
    /// Block-parallel transpose-convert. Counting: disjoint nnz slices
    /// into private per-thread count arrays, merged serially (one shared
    /// pass; falls back to column-block rescans when the private arrays
    /// would blow the memory budget). Scatter: columns partitioned into
    /// contiguous nnz-balanced blocks, each thread placing only the
    /// entries whose column falls in its block into disjoint slices of
    /// `indices`/`values` (no atomics; each thread re-reads the row
    /// stream, but writes stay block-local). Every entry's final position
    /// depends only on the counting sort, so the result is **identical**
    /// to the serial [`CscMatrix::from_csr`] at any thread count.
    pub fn from_csr_threaded(csr: &CsrMatrix, threads: usize) -> Self {
        if threads <= 1 || csr.n_cols() < 2 || csr.nnz() == 0 {
            return Self::from_csr(csr);
        }
        let n_rows = csr.n_rows();
        let n_cols = csr.n_cols();
        let nnz = csr.nnz();
        let cols_flat = csr.col_indices();

        // ---- phase 1: per-column counts ---------------------------------
        // Preferred: each thread counts a disjoint slice of the flat index
        // stream into a private count array, merged serially — one shared
        // pass over the nnz stream total. Falls back to column-block
        // rescans (threads × nnz reads, but no extra memory) when the
        // private arrays would be large (KDDA-scale D × many cores).
        let mut counts = vec![0usize; n_cols];
        const COUNT_MEM_BUDGET: usize = 1 << 24; // ≤ 64 MB of u32 counts total
        let chunk_nnz = nnz.div_ceil(threads);
        if n_cols.saturating_mul(threads) <= COUNT_MEM_BUDGET && chunk_nnz <= u32::MAX as usize
        {
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..threads)
                    .map(|t| {
                        let lo = (t * chunk_nnz).min(nnz);
                        let hi = ((t + 1) * chunk_nnz).min(nnz);
                        let slice = &cols_flat[lo..hi];
                        s.spawn(move || {
                            let mut local = vec![0u32; n_cols];
                            for &j in slice {
                                local[j as usize] += 1;
                            }
                            local
                        })
                    })
                    .collect();
                for h in handles {
                    let local = h.join().expect("count worker panicked");
                    for (c, l) in counts.iter_mut().zip(local) {
                        *c += l as usize;
                    }
                }
            });
        } else {
            let block = n_cols.div_ceil(threads);
            std::thread::scope(|s| {
                let mut rest: &mut [usize] = &mut counts;
                let mut lo = 0usize;
                while !rest.is_empty() {
                    let len = rest.len().min(block);
                    let (chunk, tail) = rest.split_at_mut(len);
                    rest = tail;
                    let hi = lo + len;
                    s.spawn(move || {
                        for &j in cols_flat {
                            let j = j as usize;
                            if j >= lo && j < hi {
                                chunk[j - lo] += 1;
                            }
                        }
                    });
                    lo = hi;
                }
            });
        }
        let mut indptr = vec![0usize; n_cols + 1];
        for j in 0..n_cols {
            indptr[j + 1] = indptr[j] + counts[j];
        }

        // ---- phase 2: scatter into nnz-balanced column blocks ----------
        let mut indices = vec![0u32; nnz];
        let mut values = vec![0.0f32; nnz];
        let ranges = super::balanced_ranges(&indptr, threads);
        std::thread::scope(|s| {
            let mut rest_i: &mut [u32] = &mut indices;
            let mut rest_v: &mut [f32] = &mut values;
            let indptr_ref: &[usize] = &indptr;
            for r in ranges {
                let span = indptr_ref[r.end] - indptr_ref[r.start];
                let (ci, ti) = rest_i.split_at_mut(span);
                let (cv, tv) = rest_v.split_at_mut(span);
                rest_i = ti;
                rest_v = tv;
                if r.is_empty() {
                    continue;
                }
                s.spawn(move || {
                    let base = indptr_ref[r.start];
                    // block-local cursors, offset so writes index `ci`/`cv`
                    let mut cursor: Vec<usize> =
                        indptr_ref[r.start..r.end].iter().map(|&p| p - base).collect();
                    for i in 0..n_rows {
                        let (idx, val) = csr.row_raw(i);
                        for (&j, &v) in idx.iter().zip(val) {
                            let j = j as usize;
                            if j >= r.start && j < r.end {
                                let p = cursor[j - r.start];
                                ci[p] = i as u32;
                                cv[p] = v;
                                cursor[j - r.start] = p + 1;
                            }
                        }
                    }
                });
            }
        });
        Self { n_rows, n_cols, indptr, indices, values }
    }

    /// Transpose-convert a CSR matrix with a counting sort: O(nnz + D).
    pub fn from_csr(csr: &CsrMatrix) -> Self {
        let n_rows = csr.n_rows();
        let n_cols = csr.n_cols();
        let nnz = csr.nnz();
        let mut indptr = vec![0usize; n_cols + 1];
        for i in 0..n_rows {
            let (idx, _) = csr.row_raw(i);
            for &j in idx {
                indptr[j as usize + 1] += 1;
            }
        }
        for j in 0..n_cols {
            indptr[j + 1] += indptr[j];
        }
        let mut cursor = indptr.clone();
        let mut indices = vec![0u32; nnz];
        let mut values = vec![0.0f32; nnz];
        for i in 0..n_rows {
            let (idx, val) = csr.row_raw(i);
            for (&j, &v) in idx.iter().zip(val) {
                let p = cursor[j as usize];
                indices[p] = i as u32;
                values[p] = v;
                cursor[j as usize] = p + 1;
            }
        }
        Self { n_rows, n_cols, indptr, indices, values }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn col_nnz(&self, j: usize) -> usize {
        self.indptr[j + 1] - self.indptr[j]
    }

    /// Iterate the nonzeros of column `j` as `(row, value)`.
    #[inline]
    pub fn col(&self, j: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let lo = self.indptr[j];
        let hi = self.indptr[j + 1];
        self.indices[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&i, &v)| (i as usize, v))
    }

    /// Raw slices of column `j` — hot-path accessor.
    #[inline]
    pub fn col_raw(&self, j: usize) -> (&[u32], &[f32]) {
        let lo = self.indptr[j];
        let hi = self.indptr[j + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// `out[j] = Σ_i X[i,j] · q[i]` for every column — the `Xᵀq` product
    /// driven from the column side. Because each column's rows are stored
    /// ascending, the per-column addition sequence is exactly the one the
    /// CSR-driven [`super::csr::CsrMatrix::matvec_t_add`] performs into a
    /// zeroed output, so the two are bit-identical (the solvers' parallel
    /// bootstrap relies on this).
    pub fn matvec_t(&self, q: &[f64], out: &mut [f64]) {
        assert_eq!(q.len(), self.n_rows);
        assert_eq!(out.len(), self.n_cols);
        self.matvec_t_range(q, 0..self.n_cols, out);
    }

    /// The column-range slice of [`CscMatrix::matvec_t`]:
    /// `out[j - cols.start] = Σ_i X[i,j] · q[i]` for `j ∈ cols`.
    pub fn matvec_t_range(&self, q: &[f64], cols: std::ops::Range<usize>, out: &mut [f64]) {
        assert_eq!(out.len(), cols.len());
        for (slot, j) in out.iter_mut().zip(cols) {
            let (idx, val) = self.col_raw(j);
            let mut acc = 0.0f64;
            for (&i, &v) in idx.iter().zip(val) {
                acc += v as f64 * q[i as usize];
            }
            *slot = acc;
        }
    }

    /// Block-parallel `out = Xᵀq`: columns split into `threads` contiguous
    /// nnz-balanced blocks, each writing a disjoint slice of `out` — no
    /// atomics, and bit-identical to [`CscMatrix::matvec_t`] (each column
    /// is still summed by exactly one thread, rows ascending) at any
    /// thread count. This is Algorithm 2's `O(N·S_c)` dense first
    /// iteration (`α = Xᵀq̄`), the one phase of the fast solver that still
    /// touches every nonzero.
    pub fn matvec_t_par(&self, q: &[f64], out: &mut [f64], threads: usize) {
        assert_eq!(q.len(), self.n_rows);
        assert_eq!(out.len(), self.n_cols);
        if threads <= 1 || self.n_cols < 2 {
            return self.matvec_t(q, out);
        }
        let ranges = super::balanced_ranges(&self.indptr, threads);
        std::thread::scope(|s| {
            let mut rest: &mut [f64] = out;
            for r in ranges {
                let (chunk, tail) = rest.split_at_mut(r.len());
                rest = tail;
                s.spawn(move || self.matvec_t_range(q, r, chunk));
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_csr() -> CsrMatrix {
        // [[1,0,2],[0,3,0],[4,0,5]]
        CsrMatrix::from_parts(
            3,
            3,
            vec![0, 2, 3, 5],
            vec![0, 2, 1, 0, 2],
            vec![1.0, 2.0, 3.0, 4.0, 5.0],
        )
    }

    #[test]
    fn conversion_preserves_entries() {
        let csr = sample_csr();
        let csc = CscMatrix::from_csr(&csr);
        assert_eq!(csc.nnz(), 5);
        let c0: Vec<_> = csc.col(0).collect();
        assert_eq!(c0, vec![(0, 1.0), (2, 4.0)]);
        let c1: Vec<_> = csc.col(1).collect();
        assert_eq!(c1, vec![(1, 3.0)]);
        let c2: Vec<_> = csc.col(2).collect();
        assert_eq!(c2, vec![(0, 2.0), (2, 5.0)]);
    }

    #[test]
    fn rows_within_column_are_sorted() {
        // from_csr visits rows in order, so each column's rows come out
        // ascending — the Alg 2 inner loop relies on this for locality.
        let csc = CscMatrix::from_csr(&sample_csr());
        for j in 0..3 {
            let rows: Vec<_> = csc.col(j).map(|(i, _)| i).collect();
            let mut sorted = rows.clone();
            sorted.sort_unstable();
            assert_eq!(rows, sorted);
        }
    }

    #[test]
    fn matvec_t_matches_csr() {
        let csr = sample_csr();
        let csc = CscMatrix::from_csr(&csr);
        let q = [1.0, 2.0, 3.0];
        let mut a = vec![0.0; 3];
        let mut b = vec![0.0; 3];
        csr.matvec_t_add(&q, &mut a);
        csc.matvec_t(&q, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_column() {
        let csr = CsrMatrix::from_parts(2, 4, vec![0, 1, 2], vec![0, 3], vec![1.0, 2.0]);
        let csc = CscMatrix::from_csr(&csr);
        assert_eq!(csc.col_nnz(1), 0);
        assert_eq!(csc.col_nnz(2), 0);
        assert_eq!(csc.col(1).count(), 0);
    }

    fn zipfish_csr(seed: u64) -> CsrMatrix {
        // Paper-shaped skewed matrix via the synth generator (Zipf column
        // popularity, empty columns, ragged rows).
        crate::sparse::synth::SynthConfig {
            name: "csc-par".into(),
            n_rows: 300,
            n_cols: 500,
            avg_row_nnz: 9.0,
            zipf_exponent: 1.2,
            n_informative: 12,
            n_dense: 2,
            label_noise: 0.0,
            bias_col: true,
        }
        .generate(seed)
        .csr
        .clone()
    }

    #[test]
    fn threaded_conversion_identical_to_serial() {
        let csr = zipfish_csr(11);
        let serial = CscMatrix::from_csr(&csr);
        for threads in [2usize, 3, 8, 64] {
            let par = CscMatrix::from_csr_threaded(&csr, threads);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn matvec_t_par_bit_identical_all_drivers() {
        let csr = zipfish_csr(13);
        let csc = CscMatrix::from_csr(&csr);
        // +0.1 keeps every q_i nonzero: matvec_t_add skips zero rows while
        // the CSC driver includes them, which is only bit-neutral when no
        // exact zeros occur (the solvers' q̄ is ±σ-residuals, never 0).
        let q: Vec<f64> = (0..csr.n_rows()).map(|i| (i as f64 * 0.71 + 0.1).sin()).collect();
        // CSR-driven reference (the pre-fusion bootstrap path)
        let mut csr_driven = vec![0.0f64; csr.n_cols()];
        csr.matvec_t_add(&q, &mut csr_driven);
        let mut serial = vec![f64::NAN; csr.n_cols()];
        csc.matvec_t(&q, &mut serial);
        for (a, b) in csr_driven.iter().zip(&serial) {
            assert_eq!(a.to_bits(), b.to_bits(), "CSC column order drifted from CSR");
        }
        for threads in [2usize, 4, 32] {
            let mut par = vec![f64::NAN; csr.n_cols()];
            csc.matvec_t_par(&q, &mut par, threads);
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }
}
