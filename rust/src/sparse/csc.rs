//! Compressed Sparse Column matrix: the `X[:,j]` view.
//!
//! Algorithm 2's inner loop is "for all rows i of X with feature j" — that
//! is exactly one CSC column scan (`S_r` entries on average). Built once
//! from the CSR view at dataset load; the two views share nothing so each
//! stays contiguous for its own scan direction.

use super::compact::{CompactIndices, IndexSeg};
use super::csr::CsrMatrix;
use crate::fw::scan::ScanKernel;

/// Raw-pointer wrapper that lets the scoped scatter threads share the
/// output arrays. Safe to send because every write index is provably
/// disjoint across threads (see the SAFETY comment at the write site and
/// rust/DESIGN.md §6.3).
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}

#[derive(Clone, Debug)]
pub struct CscMatrix {
    n_rows: usize,
    n_cols: usize,
    /// Column start offsets, length `n_cols + 1`.
    indptr: Vec<usize>,
    /// Row index of each stored value, length `nnz`.
    indices: Vec<u32>,
    /// Stored values, length `nnz`.
    values: Vec<f32>,
    /// Delta-compressed `u16` mirror of `indices` (DESIGN.md §6.6);
    /// `None` until [`CscMatrix::build_compact`] or when the qualifier
    /// rejects the matrix. Always valid here when built: the counting
    /// sort emits each column's rows ascending.
    compact: Option<CompactIndices>,
}

/// Structural equality on the canonical `u32` representation; the derived
/// compact stream is excluded (same contract as `CsrMatrix`).
impl PartialEq for CscMatrix {
    fn eq(&self, other: &Self) -> bool {
        self.n_rows == other.n_rows
            && self.n_cols == other.n_cols
            && self.indptr == other.indptr
            && self.indices == other.indices
            && self.values == other.values
    }
}

/// Effective worker count [`CscMatrix::from_csr_threaded`] uses for a
/// matrix of the given shape: the requested count after the serial gates
/// (tiny nnz, trivial shapes, `u32` overflow guard) and the cursor-table
/// memory cap. Pure function of the arguments. Exposed — rather than left
/// implicit in the scatter — so callers can *report* the worker count
/// actually used instead of the one requested; the cap used to clamp
/// silently, leaving bench rows attributed to phantom thread counts.
pub fn scatter_workers(threads: usize, n_cols: usize, nnz: usize) -> usize {
    if threads <= 1 || nnz < super::PAR_MIN_NNZ || n_cols < 2 || nnz > u32::MAX as usize {
        return 1;
    }
    // ≤ 256 MB of transient u32 cursors: cap workers instead of
    // rescanning. Sized so even the widest paper presets keep parallelism
    // (KDDA D ≈ 20.2M → 3 workers, Web D ≈ 16.6M → 4) while D × many-core
    // machines can't allocate unboundedly; the tables are freed before the
    // scatter returns, and matrices this wide carry nnz buffers far larger
    // than the cursors.
    const COUNT_MEM_BUDGET: usize = 1 << 26;
    threads.min((COUNT_MEM_BUDGET / n_cols).max(1)).min(nnz)
}

impl CscMatrix {
    /// Block-parallel transpose-convert with a **single-read scatter**
    /// (DESIGN.md §6.3). Counting: each thread counts a disjoint chunk of
    /// the flat column-index stream into a private count array — one
    /// shared pass. The serial merge turns the totals into `indptr` and,
    /// in the same sweep, each thread's counts into its per-(thread,
    /// column) *exclusive prefix*: the cursor where chunk `t`'s entries of
    /// column `j` start inside that column's segment. Scatter: each thread
    /// then re-walks only **its own chunk** of the entry stream (so the
    /// nnz stream is read exactly once per phase, independent of thread
    /// count — the old implementation re-read the whole row stream per
    /// thread, `O(threads × nnz)`) and writes through raw pointers into
    /// positions that are disjoint by the prefix-sum construction. Every
    /// entry's final position depends only on the counting sort, so the
    /// result is **identical** to the serial [`CscMatrix::from_csr`] at
    /// any thread count. Worker count is capped so the cursor tables stay
    /// within a fixed memory budget — fewer threads, never re-reads.
    pub fn from_csr_threaded(csr: &CsrMatrix, threads: usize) -> Self {
        let n_rows = csr.n_rows();
        let n_cols = csr.n_cols();
        let nnz = csr.nnz();
        // Serial fallback and worker cap both live in [`scatter_workers`]
        // (not at call sites — tiny matrices never pay thread-spawn
        // overhead no matter what the caller asks for, and the cursor
        // -table memory budget caps wide matrices; see that function for
        // the sizing rationale). Keeping the decision in one pure function
        // lets `Dataset` record the count actually used.
        let t_eff = scatter_workers(threads, n_cols, nnz);
        if t_eff <= 1 {
            return Self::from_csr(csr);
        }
        let chunk = nnz.div_ceil(t_eff);
        let cols_flat = csr.col_indices();
        let vals_flat = csr.values_flat();
        let row_ptr = csr.row_ptr();

        // ---- phase 1: one shared pass over the column stream → private
        // per-thread counts of the same disjoint chunks the scatter will
        // later write ----------------------------------------------------
        let mut locals: Vec<Vec<u32>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..t_eff)
                .map(|t| {
                    let lo = (t * chunk).min(nnz);
                    let hi = ((t + 1) * chunk).min(nnz);
                    let slice = &cols_flat[lo..hi];
                    s.spawn(move || {
                        let mut local = vec![0u32; n_cols];
                        for &j in slice {
                            local[j as usize] += 1;
                        }
                        local
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("count worker panicked"))
                .collect()
        });

        // ---- merge: column totals → indptr, and — same sweep — each
        // thread's counts → its exclusive per-column prefix (its scatter
        // cursor start within the column segment) ------------------------
        let mut col_nnz = vec![0u32; n_cols];
        for local in locals.iter_mut() {
            for (c, tot) in local.iter_mut().zip(col_nnz.iter_mut()) {
                let cnt = *c;
                *c = *tot;
                *tot += cnt;
            }
        }
        let mut indptr = vec![0usize; n_cols + 1];
        for j in 0..n_cols {
            indptr[j + 1] = indptr[j] + col_nnz[j] as usize;
        }

        // ---- phase 2: single-read scatter — each thread walks only its
        // own chunk of the entry stream, recovering row indices from the
        // CSR indptr, and writes through per-(thread, column) cursors ----
        let mut indices = vec![0u32; nnz];
        let mut values = vec![0.0f32; nnz];
        let idx_out = SendPtr(indices.as_mut_ptr());
        let val_out = SendPtr(values.as_mut_ptr());
        let indptr_ref: &[usize] = &indptr;
        std::thread::scope(|s| {
            for (t, mut cursor) in locals.into_iter().enumerate() {
                let lo = (t * chunk).min(nnz);
                let hi = ((t + 1) * chunk).min(nnz);
                if lo >= hi {
                    continue;
                }
                s.spawn(move || {
                    // last row starting at or before flat position `lo`
                    let mut i = row_ptr.partition_point(|&p| p <= lo) - 1;
                    let mut p = lo;
                    while p < hi {
                        while row_ptr[i + 1] <= p {
                            i += 1; // skip empty (and exhausted) rows
                        }
                        let end = row_ptr[i + 1].min(hi);
                        let iu = i as u32;
                        for (&j, &v) in cols_flat[p..end].iter().zip(&vals_flat[p..end]) {
                            let ju = j as usize;
                            let dst = indptr_ref[ju] + cursor[ju] as usize;
                            cursor[ju] += 1;
                            // SAFETY: thread `t` writes column `j` exactly
                            // at offsets [prefix_t(j), prefix_t(j) +
                            // count_t(j)) within the column's segment,
                            // where prefix_t is the exclusive prefix of
                            // the phase-1 private counts — disjoint across
                            // threads by construction, and their union is
                            // [indptr[j], indptr[j+1]) ⊂ [0, nnz). No two
                            // threads can ever produce the same `dst`.
                            unsafe {
                                *idx_out.0.add(dst) = iu;
                                *val_out.0.add(dst) = v;
                            }
                        }
                        p = end;
                    }
                });
            }
        });
        Self { n_rows, n_cols, indptr, indices, values, compact: None }
    }

    /// Transpose-convert a CSR matrix with a counting sort: O(nnz + D).
    pub fn from_csr(csr: &CsrMatrix) -> Self {
        let n_rows = csr.n_rows();
        let n_cols = csr.n_cols();
        let nnz = csr.nnz();
        let mut indptr = vec![0usize; n_cols + 1];
        for i in 0..n_rows {
            let (idx, _) = csr.row_raw(i);
            for &j in idx {
                indptr[j as usize + 1] += 1;
            }
        }
        for j in 0..n_cols {
            indptr[j + 1] += indptr[j];
        }
        let mut cursor = indptr.clone();
        let mut indices = vec![0u32; nnz];
        let mut values = vec![0.0f32; nnz];
        for i in 0..n_rows {
            let (idx, val) = csr.row_raw(i);
            for (&j, &v) in idx.iter().zip(val) {
                let p = cursor[j as usize];
                indices[p] = i as u32;
                values[p] = v;
                cursor[j as usize] = p + 1;
            }
        }
        Self { n_rows, n_cols, indptr, indices, values, compact: None }
    }

    /// Build (or rebuild) the delta-compressed `u16` index mirror
    /// (DESIGN.md §6.6). Called once by `Dataset::new`; idempotent.
    pub fn build_compact(&mut self) {
        self.compact = CompactIndices::build(&self.indptr, &self.indices);
    }

    /// Drop the compact mirror, pinning the matrix to the `u32` substrate.
    pub fn clear_compact(&mut self) {
        self.compact = None;
    }

    /// `"u16-delta"` after a successful build, else `"u32"`.
    pub fn index_kind(&self) -> &'static str {
        if self.compact.is_some() {
            "u16-delta"
        } else {
            "u32"
        }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn col_nnz(&self, j: usize) -> usize {
        self.indptr[j + 1] - self.indptr[j]
    }

    /// Iterate the nonzeros of column `j` as `(row, value)`.
    #[inline]
    pub fn col(&self, j: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let lo = self.indptr[j];
        let hi = self.indptr[j + 1];
        self.indices[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&i, &v)| (i as usize, v))
    }

    /// Raw slices of column `j` — the canonical `u32` accessor. Hot loops
    /// should prefer [`CscMatrix::col_seg`].
    #[inline]
    pub fn col_raw(&self, j: usize) -> (&[u32], &[f32]) {
        let lo = self.indptr[j];
        let hi = self.indptr[j + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Column `j` in whichever index representation the matrix carries —
    /// the hot-path accessor the scan kernels consume.
    #[inline]
    pub fn col_seg(&self, j: usize) -> (IndexSeg<'_>, &[f32]) {
        let lo = self.indptr[j];
        let hi = self.indptr[j + 1];
        let vals = &self.values[lo..hi];
        match &self.compact {
            Some(c) => (IndexSeg::U16 { words: c.seg_words(j), nnz: hi - lo }, vals),
            None => (IndexSeg::U32(&self.indices[lo..hi]), vals),
        }
    }

    /// Bytes a full sweep of the index structure moves (per-segment byte
    /// counts come from `IndexSeg::index_bytes`).
    pub fn index_bytes_total(&self) -> u64 {
        match &self.compact {
            Some(c) => 2 * c.total_words() as u64,
            None => 4 * self.nnz() as u64,
        }
    }

    /// How a full column sweep splits under `kern`'s dispatcher —
    /// `(direct_segments, scratch_segments, scratch_nnz)`, the CSC mirror
    /// of [`CsrMatrix::scan_split`] (DESIGN.md §6.7; the threshold rule
    /// lives in [`ScanKernel::split_segments`]). `(0, 0, 0)` on the `u32`
    /// substrate; O(n_cols).
    pub fn scan_split(&self, kern: ScanKernel) -> (u64, u64, u64) {
        if self.compact.is_none() {
            return (0, 0, 0);
        }
        kern.split_segments(&self.indptr)
    }

    /// `out[j] = Σ_i X[i,j] · q[i]` for every column — the `Xᵀq` product
    /// driven from the column side. Because each column's rows are stored
    /// ascending, the per-column addition sequence is exactly the one the
    /// CSR-driven [`super::csr::CsrMatrix::matvec_t_add`] performs into a
    /// zeroed output, so the two are bit-identical (the solvers' parallel
    /// bootstrap relies on this).
    pub fn matvec_t(&self, q: &[f64], out: &mut [f64]) {
        assert_eq!(q.len(), self.n_rows);
        assert_eq!(out.len(), self.n_cols);
        self.matvec_t_range(q, 0..self.n_cols, out);
    }

    /// The column-range slice of [`CscMatrix::matvec_t`]:
    /// `out[j - cols.start] = Σ_i X[i,j] · q[i]` for `j ∈ cols`.
    pub fn matvec_t_range(&self, q: &[f64], cols: std::ops::Range<usize>, out: &mut [f64]) {
        self.matvec_t_range_in(q, cols, out, &mut Vec::new());
    }

    /// Scratch-threaded body of [`CscMatrix::matvec_t_range`], dispatching
    /// through the process-wide [`ScanKernel::from_env`].
    pub fn matvec_t_range_in(
        &self,
        q: &[f64],
        cols: std::ops::Range<usize>,
        out: &mut [f64],
        scratch: &mut Vec<u32>,
    ) {
        self.matvec_t_range_scan(q, cols, out, scratch, ScanKernel::from_env());
    }

    /// Dispatcher-threaded body of [`CscMatrix::matvec_t_range`]: short
    /// compact columns ride the fused direct-decode arm, long ones reuse
    /// one decode scratch across the whole range (untouched on `u32`).
    pub fn matvec_t_range_scan(
        &self,
        q: &[f64],
        cols: std::ops::Range<usize>,
        out: &mut [f64],
        scratch: &mut Vec<u32>,
        kern: ScanKernel,
    ) {
        assert_eq!(out.len(), cols.len());
        for (slot, j) in out.iter_mut().zip(cols) {
            let (seg, vals) = self.col_seg(j);
            *slot = kern.dot(seg, vals, q, scratch);
        }
    }

    /// Block-parallel `out = Xᵀq`: columns split into `threads` contiguous
    /// nnz-balanced blocks, each writing a disjoint slice of `out` — no
    /// atomics, and bit-identical to [`CscMatrix::matvec_t`] (each column
    /// is still summed by exactly one thread, rows ascending) at any
    /// thread count. This is Algorithm 2's `O(N·S_c)` dense first
    /// iteration (`α = Xᵀq̄`), the one phase of the fast solver that still
    /// touches every nonzero. The [`super::PAR_MIN_NNZ`] serial-fallback
    /// gate lives here, not at call sites.
    pub fn matvec_t_par(&self, q: &[f64], out: &mut [f64], threads: usize) {
        self.matvec_t_par_scan(q, out, threads, ScanKernel::from_env());
    }

    /// Dispatcher-threaded body of [`CscMatrix::matvec_t_par`] — the
    /// solvers' bootstrap entry point, so an explicit
    /// `FwConfig::direct_max_nnz` governs the bootstrap sweep too (each
    /// worker allocates its own decode scratch, exactly as before).
    pub fn matvec_t_par_scan(&self, q: &[f64], out: &mut [f64], threads: usize, kern: ScanKernel) {
        assert_eq!(q.len(), self.n_rows);
        assert_eq!(out.len(), self.n_cols);
        let threads = if self.nnz() < super::PAR_MIN_NNZ { 1 } else { threads };
        if threads <= 1 || self.n_cols < 2 {
            return self.matvec_t_range_scan(q, 0..self.n_cols, out, &mut Vec::new(), kern);
        }
        let ranges = super::balanced_ranges(&self.indptr, threads);
        std::thread::scope(|s| {
            let mut rest: &mut [f64] = out;
            for r in ranges {
                let (chunk, tail) = rest.split_at_mut(r.len());
                rest = tail;
                s.spawn(move || self.matvec_t_range_scan(q, r, chunk, &mut Vec::new(), kern));
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_csr() -> CsrMatrix {
        // [[1,0,2],[0,3,0],[4,0,5]]
        CsrMatrix::from_parts(
            3,
            3,
            vec![0, 2, 3, 5],
            vec![0, 2, 1, 0, 2],
            vec![1.0, 2.0, 3.0, 4.0, 5.0],
        )
    }

    #[test]
    fn conversion_preserves_entries() {
        let csr = sample_csr();
        let csc = CscMatrix::from_csr(&csr);
        assert_eq!(csc.nnz(), 5);
        let c0: Vec<_> = csc.col(0).collect();
        assert_eq!(c0, vec![(0, 1.0), (2, 4.0)]);
        let c1: Vec<_> = csc.col(1).collect();
        assert_eq!(c1, vec![(1, 3.0)]);
        let c2: Vec<_> = csc.col(2).collect();
        assert_eq!(c2, vec![(0, 2.0), (2, 5.0)]);
    }

    #[test]
    fn rows_within_column_are_sorted() {
        // from_csr visits rows in order, so each column's rows come out
        // ascending — the Alg 2 inner loop relies on this for locality.
        let csc = CscMatrix::from_csr(&sample_csr());
        for j in 0..3 {
            let rows: Vec<_> = csc.col(j).map(|(i, _)| i).collect();
            let mut sorted = rows.clone();
            sorted.sort_unstable();
            assert_eq!(rows, sorted);
        }
    }

    #[test]
    fn matvec_t_matches_csr() {
        let csr = sample_csr();
        let csc = CscMatrix::from_csr(&csr);
        let q = [1.0, 2.0, 3.0];
        let mut a = vec![0.0; 3];
        let mut b = vec![0.0; 3];
        csr.matvec_t_add(&q, &mut a);
        csc.matvec_t(&q, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_column() {
        let csr = CsrMatrix::from_parts(2, 4, vec![0, 1, 2], vec![0, 3], vec![1.0, 2.0]);
        let csc = CscMatrix::from_csr(&csr);
        assert_eq!(csc.col_nnz(1), 0);
        assert_eq!(csc.col_nnz(2), 0);
        assert_eq!(csc.col(1).count(), 0);
    }

    fn zipfish_csr(seed: u64) -> CsrMatrix {
        // Paper-shaped skewed matrix via the synth generator (Zipf column
        // popularity, empty columns, ragged rows).
        crate::sparse::synth::SynthConfig {
            name: "csc-par".into(),
            n_rows: 300,
            n_cols: 500,
            avg_row_nnz: 9.0,
            zipf_exponent: 1.2,
            n_informative: 12,
            n_dense: 2,
            label_noise: 0.0,
            bias_col: true,
        }
        .generate(seed)
        .csr
        .clone()
    }

    fn zipfish_csr_big(seed: u64) -> CsrMatrix {
        // Same Zipf shape but above PAR_MIN_NNZ, so the in-kernel gate
        // does not serialize and the threaded paths genuinely run.
        crate::sparse::synth::SynthConfig {
            name: "csc-par-big".into(),
            n_rows: 4000,
            n_cols: 1500,
            avg_row_nnz: 12.0,
            zipf_exponent: 1.2,
            n_informative: 12,
            n_dense: 2,
            label_noise: 0.0,
            bias_col: true,
        }
        .generate(seed)
        .csr
        .clone()
    }

    #[test]
    fn threaded_conversion_identical_to_serial() {
        // below the gate: serialized inside the entry point, still identical
        let csr = zipfish_csr(11);
        let serial = CscMatrix::from_csr(&csr);
        for threads in [2usize, 3, 8, 64] {
            let par = CscMatrix::from_csr_threaded(&csr, threads);
            assert_eq!(par, serial, "threads={threads}");
        }
        // above the gate: the parallel scatter actually runs
        let csr = zipfish_csr_big(11);
        assert!(csr.nnz() >= crate::sparse::PAR_MIN_NNZ, "fixture must clear the gate");
        let serial = CscMatrix::from_csr(&csr);
        for threads in [2usize, 3, 8, 64] {
            let par = CscMatrix::from_csr_threaded(&csr, threads);
            assert_eq!(par, serial, "big threads={threads}");
        }
    }

    #[test]
    fn threaded_conversion_handles_ragged_and_empty_extremes() {
        // Adversarial shape for the single-read scatter: leading/trailing
        // empty columns, empty rows (chunk boundaries must skip them), one
        // hot column holding most of the mass (many threads write the same
        // column via their disjoint prefix cursors), and ragged rows.
        // 24k rows × 1.5 nnz/row keeps the fixture above PAR_MIN_NNZ so
        // the in-kernel gate does not serialize it away.
        let n_rows = 24_000usize;
        let n_cols = 12usize;
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for i in 0..n_rows {
            match i % 4 {
                0 => {} // empty row
                1 => {
                    // hot column 5 only
                    indices.push(5);
                    values.push(i as f32);
                }
                2 => {
                    // ragged: hot column + a tail column (never 0 or 11)
                    indices.extend([1, 5, 9]);
                    values.extend([1.0, 2.0 + i as f32, 3.0]);
                }
                _ => {
                    indices.extend([5, 10]);
                    values.extend([-(i as f32), 0.5]);
                }
            }
            indptr.push(indices.len());
        }
        let csr = CsrMatrix::from_parts(n_rows, n_cols, indptr, indices, values);
        assert!(csr.nnz() >= crate::sparse::PAR_MIN_NNZ, "fixture must clear the gate");
        let serial = CscMatrix::from_csr(&csr);
        assert_eq!(serial.col_nnz(0), 0, "want empty leading column");
        assert_eq!(serial.col_nnz(11), 0, "want empty trailing column");
        for threads in [1usize, 2, 4, 16, 33] {
            assert_eq!(
                CscMatrix::from_csr_threaded(&csr, threads),
                serial,
                "threads={threads}"
            );
        }
    }

    #[test]
    fn compact_column_kernels_bit_identical_including_dense_column() {
        // zipfish includes URL-style dense columns (n_dense = 2) plus the
        // bias column — every row appears in them, deltas of 1 throughout.
        let csr = zipfish_csr(29);
        let plain = CscMatrix::from_csr(&csr);
        let mut compact = plain.clone();
        compact.build_compact();
        assert_eq!(compact.index_kind(), "u16-delta");
        assert_eq!(plain, compact, "compact mirror must not affect equality");
        assert!(compact.index_bytes_total() < plain.index_bytes_total());
        let q: Vec<f64> = (0..csr.n_rows()).map(|i| (i as f64 * 0.71 + 0.1).sin()).collect();
        let mut a = vec![0.0f64; csr.n_cols()];
        let mut b = vec![f64::NAN; csr.n_cols()];
        plain.matvec_t(&q, &mut a);
        compact.matvec_t(&q, &mut b);
        for (j, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "col {j} diverged");
        }
    }

    #[test]
    fn scan_split_mirrors_arm_dispatch() {
        use crate::fw::scan::SegArm;
        let csr = zipfish_csr(31);
        let plain = CscMatrix::from_csr(&csr);
        let mut compact = plain.clone();
        compact.build_compact();
        assert_eq!(compact.index_kind(), "u16-delta");
        let kern = ScanKernel::with_threshold(8);
        assert_eq!(plain.scan_split(kern), (0, 0, 0), "u32 substrate has no arms");
        // the analytic split must agree with per-segment arm dispatch
        let (mut d, mut s, mut n) = (0u64, 0u64, 0u64);
        for j in 0..compact.n_cols() {
            let (seg, vals) = compact.col_seg(j);
            if vals.is_empty() {
                continue;
            }
            match kern.arm(&seg) {
                SegArm::Direct => d += 1,
                SegArm::Scratch => {
                    s += 1;
                    n += vals.len() as u64;
                }
                SegArm::U32 => unreachable!("compact matrix"),
            }
        }
        assert_eq!(compact.scan_split(kern), (d, s, n));
        // the zipf fixture has both tail columns (≤ 8 nnz) and dense ones
        assert!(d > 0 && s > 0, "fixture must exercise both arms at thr=8");
    }

    #[test]
    fn matvec_t_par_above_gate_bit_identical() {
        let csr = zipfish_csr_big(17);
        assert!(csr.nnz() >= crate::sparse::PAR_MIN_NNZ, "fixture must clear the gate");
        let csc = CscMatrix::from_csr(&csr);
        let q: Vec<f64> = (0..csr.n_rows()).map(|i| (i as f64 * 0.31 + 0.1).cos()).collect();
        let mut serial = vec![0.0f64; csr.n_cols()];
        csc.matvec_t(&q, &mut serial);
        for threads in [2usize, 4, 32] {
            let mut par = vec![f64::NAN; csr.n_cols()];
            csc.matvec_t_par(&q, &mut par, threads);
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn matvec_t_par_bit_identical_all_drivers() {
        let csr = zipfish_csr(13);
        let csc = CscMatrix::from_csr(&csr);
        // +0.1 keeps every q_i nonzero: matvec_t_add skips zero rows while
        // the CSC driver includes them, which is only bit-neutral when no
        // exact zeros occur (the solvers' q̄ is ±σ-residuals, never 0).
        let q: Vec<f64> = (0..csr.n_rows()).map(|i| (i as f64 * 0.71 + 0.1).sin()).collect();
        // CSR-driven reference (the pre-fusion bootstrap path)
        let mut csr_driven = vec![0.0f64; csr.n_cols()];
        csr.matvec_t_add(&q, &mut csr_driven);
        let mut serial = vec![f64::NAN; csr.n_cols()];
        csc.matvec_t(&q, &mut serial);
        for (a, b) in csr_driven.iter().zip(&serial) {
            assert_eq!(a.to_bits(), b.to_bits(), "CSC column order drifted from CSR");
        }
        for threads in [2usize, 4, 32] {
            let mut par = vec![f64::NAN; csr.n_cols()];
            csc.matvec_t_par(&q, &mut par, threads);
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }
}
