//! Delta-compressed `u16` index streams — the compact sparse substrate
//! (DESIGN.md §6.6).
//!
//! The Alg 2 hot loops are memory-bound gathers whose traffic is dominated
//! by the index streams (`sparse/csr.rs` already chose `u32` over `usize`
//! for exactly this reason). Within one CSR row or CSC column the indices
//! are sorted ascending, so consecutive *deltas* are small on every
//! paper-shaped dataset: storing deltas as `u16` words halves index
//! traffic again. Deltas that do not fit (first index of a segment far
//! from zero, or a gap ≥ 2¹⁶ − 1) are carried by **escape blocks**: the
//! marker word [`ESCAPE`] followed by the full `u32` delta in two words.
//!
//! A per-matrix **qualifier** keeps the encoding honest: [`CompactIndices::build`]
//! returns `None` — and the matrix stays on the plain `u32` substrate —
//! when any segment is unsorted (hand-built matrices) or when escape
//! blocks are so common that the `u16` stream would not be strictly
//! smaller than the `u32` one it mirrors. The compact stream is *derived*
//! data: the `u32` stream remains the canonical representation (builders,
//! I/O, and equality all use it), so carrying both costs at most +50%
//! index memory while the hot loops read only the half-width stream.
//!
//! Decoding is exact and order-preserving: [`decode_words`] reproduces the
//! original `u32` indices in their original order, which is what makes
//! every kernel routed through [`crate::fw::scan`] bit-identical to its
//! `u32` counterpart.

/// Marker word opening a 3-word escape block: `ESCAPE, lo16, hi16` carries
/// a full `u32` delta. A delta equal to `ESCAPE` itself must be escaped,
/// so plain words cover deltas `0 ..= 2¹⁶ − 2`.
pub const ESCAPE: u16 = u16::MAX;

/// Delta-encoded `u16` mirror of one CSR/CSC index array, segmented the
/// same way (one segment per row / column).
#[derive(Clone, Debug, PartialEq)]
pub struct CompactIndices {
    /// Word offsets per segment, length `n_segments + 1`.
    ptr: Vec<usize>,
    /// The delta/escape word stream.
    words: Vec<u16>,
}

impl CompactIndices {
    /// Encode `indices` segmented by `indptr` (the standard CSR/CSC pair).
    /// Returns `None` when the encoding would not pay: a segment is not
    /// sorted ascending (deltas would be negative), or the `u16` stream is
    /// not strictly smaller than the `4·nnz`-byte `u32` stream it mirrors
    /// (escape-heavy matrices, and the trivial `nnz = 0` case).
    pub fn build(indptr: &[usize], indices: &[u32]) -> Option<Self> {
        let n_seg = indptr.len() - 1;
        let nnz = indices.len();
        let mut ptr = Vec::with_capacity(n_seg + 1);
        // nnz words exactly when no escapes occur; reserve a little slack
        let mut words: Vec<u16> = Vec::with_capacity(nnz + nnz / 8 + 4);
        ptr.push(0);
        for s in 0..n_seg {
            let mut prev = 0u32; // first index is encoded as a delta from 0
            for &j in &indices[indptr[s]..indptr[s + 1]] {
                if j < prev {
                    return None; // unsorted segment: stay on u32
                }
                let delta = j - prev;
                if delta < ESCAPE as u32 {
                    words.push(delta as u16);
                } else {
                    words.push(ESCAPE);
                    words.push(delta as u16); // low 16 bits
                    words.push((delta >> 16) as u16); // high 16 bits
                }
                prev = j;
            }
            ptr.push(words.len());
        }
        // Qualifier: 2 bytes/word must strictly beat 4 bytes/index.
        if 2 * words.len() >= 4 * nnz {
            return None;
        }
        Some(Self { ptr, words })
    }

    pub fn n_segments(&self) -> usize {
        self.ptr.len() - 1
    }

    /// The word stream of segment `s` (row `s` / column `s`).
    #[inline]
    pub fn seg_words(&self, s: usize) -> &[u16] {
        &self.words[self.ptr[s]..self.ptr[s + 1]]
    }

    /// Word count of segment `s` — O(1), for byte-traffic accounting.
    #[inline]
    pub fn seg_word_count(&self, s: usize) -> usize {
        self.ptr[s + 1] - self.ptr[s]
    }

    /// Total words across all segments.
    pub fn total_words(&self) -> usize {
        self.words.len()
    }
}

/// One segment of an index array, in whichever representation the matrix
/// carries. The scan kernels ([`crate::fw::scan`]) accept either and
/// produce bit-identical results.
#[derive(Clone, Copy)]
pub enum IndexSeg<'a> {
    /// Plain `u32` indices — the canonical fallback substrate.
    U32(&'a [u32]),
    /// Delta-compressed word stream holding `nnz` indices.
    U16 { words: &'a [u16], nnz: usize },
}

impl IndexSeg<'_> {
    /// Number of indices in the segment.
    #[inline]
    pub fn nnz(&self) -> usize {
        match self {
            IndexSeg::U32(idx) => idx.len(),
            IndexSeg::U16 { nnz, .. } => *nnz,
        }
    }

    /// Bytes this segment's index stream occupies (the traffic a scan of
    /// it moves): `4·nnz` for `u32`, `2·words` for the compact stream.
    #[inline]
    pub fn index_bytes(&self) -> u64 {
        match self {
            IndexSeg::U32(idx) => 4 * idx.len() as u64,
            IndexSeg::U16 { words, .. } => 2 * words.len() as u64,
        }
    }
}

/// Decode one segment's word stream into `out` (cleared first), restoring
/// the original `u32` indices in their original order. `nnz` is the known
/// index count (from the matrix `indptr`), used only to size `out`.
#[inline]
pub fn decode_words(words: &[u16], nnz: usize, out: &mut Vec<u32>) {
    out.clear();
    out.reserve(nnz);
    let mut prev = 0u32;
    let mut i = 0;
    while i < words.len() {
        let w0 = words[i];
        let delta = if w0 != ESCAPE {
            i += 1;
            w0 as u32
        } else {
            debug_assert!(i + 2 < words.len(), "truncated escape block");
            let lo = words[i + 1] as u32;
            let hi = words[i + 2] as u32;
            i += 3;
            lo | (hi << 16)
        };
        prev = prev.wrapping_add(delta);
        out.push(prev);
    }
    debug_assert_eq!(out.len(), nnz, "decoded count != segment nnz");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(indptr: &[usize], indices: &[u32]) -> Option<CompactIndices> {
        let c = CompactIndices::build(indptr, indices)?;
        let mut out = Vec::new();
        for s in 0..c.n_segments() {
            let nnz = indptr[s + 1] - indptr[s];
            decode_words(c.seg_words(s), nnz, &mut out);
            assert_eq!(&out[..], &indices[indptr[s]..indptr[s + 1]], "segment {s}");
        }
        Some(c)
    }

    #[test]
    fn roundtrip_simple() {
        let c = roundtrip(&[0, 3, 3, 5], &[0, 2, 7, 1, 60_000]).unwrap();
        assert_eq!(c.n_segments(), 3);
        assert_eq!(c.seg_word_count(1), 0, "empty segment");
        // no escapes: one word per index
        assert_eq!(c.total_words(), 5);
    }

    #[test]
    fn escape_blocks_roundtrip() {
        // three escape deltas (a 70k first-index jump, a 130k mid-row gap,
        // a 4e9 first index near the u32 ceiling) diluted with enough
        // plain deltas that the qualifier still accepts the matrix
        let indices =
            [70_000u32, 70_001, 70_002, 70_003, 70_004, 200_000, 4_000_000_000, 4_000_000_001];
        let c = roundtrip(&[0, 6, 8], &indices).unwrap();
        // 3 escapes × 3 words + 5 plain words
        assert_eq!(c.total_words(), 14);
    }

    #[test]
    fn escape_boundary_is_exact() {
        // delta 65_534 fits a plain word; 65_535 (== ESCAPE) must escape;
        // 65_536 exercises the hi-word path. Three plain deltas per
        // segment keep the qualifier satisfied.
        let fits = roundtrip(&[0, 4], &[0, 1, 2, 65_536]).unwrap(); // tail delta 65_534
        assert_eq!(fits.total_words(), 4);
        let escaped = roundtrip(&[0, 4], &[0, 1, 2, 65_537]).unwrap(); // tail delta 65_535
        assert_eq!(escaped.total_words(), 6);
        let hi = roundtrip(&[0, 4], &[0, 1, 2, 65_538]).unwrap(); // tail delta 65_536
        assert_eq!(hi.total_words(), 6);
    }

    #[test]
    fn qualifier_is_a_strict_byte_win_boundary() {
        // 1 escape per 2 indices: words = 2 + 3·1... exactly 2·nnz words
        // would tie the u32 stream — the qualifier must reject ties.
        // [0, 65_535]: words = 1 + 3 = 4, nnz = 2 → 8 bytes vs 8 bytes.
        assert!(CompactIndices::build(&[0, 2], &[0, 65_535]).is_none());
        // one more plain word tips it into a strict win
        assert!(CompactIndices::build(&[0, 3], &[0, 1, 65_536]).is_some());
    }

    #[test]
    fn leading_and_trailing_empty_segments() {
        roundtrip(&[0, 0, 2, 2, 2], &[5, 9]).unwrap();
    }

    #[test]
    fn duplicate_indices_allowed() {
        // non-decreasing (delta 0) is legal — duplicate-summing happens
        // upstream in CooBuilder, but the encoding must not assume it
        roundtrip(&[0, 3], &[4, 4, 9]).unwrap();
    }

    #[test]
    fn unsorted_segment_disqualifies() {
        assert!(CompactIndices::build(&[0, 2], &[7, 3]).is_none());
    }

    #[test]
    fn escape_heavy_matrix_disqualifies() {
        // every index needs an escape block: 3 words (6 bytes) per index
        // vs 4 bytes on u32 — compaction must refuse
        let indices: Vec<u32> = (1..=10u32).map(|k| k * 100_000).collect();
        let indptr: Vec<usize> = (0..=10).collect();
        assert!(CompactIndices::build(&indptr, &indices).is_none());
    }

    #[test]
    fn empty_matrix_disqualifies() {
        assert!(CompactIndices::build(&[0], &[]).is_none());
        assert!(CompactIndices::build(&[0, 0, 0], &[]).is_none());
    }
}
