//! Compressed Sparse Row matrix: the `X[i,:]` view.
//!
//! Values are `f32` (dataset storage — the paper's datasets are
//! count/tf-idf features), accumulation happens in `f64` everywhere the
//! solvers touch them. Column indices are `u32` (D ≤ 4.29e9 covers the
//! paper's 20.2M-feature KDDA with room to spare) to halve index memory
//! traffic — this matters: the Alg 2 inner loop is memory-bound gathers.
//! [`CsrMatrix::build_compact`] optionally mirrors the indices as a
//! delta-compressed `u16` stream (DESIGN.md §6.6) that halves the index
//! traffic again; all scan kernels consume either representation through
//! [`crate::fw::scan`] with bit-identical results.

use super::compact::{CompactIndices, IndexSeg};
use crate::fw::scan::{self, ScanKernel, SegArm};

#[derive(Clone, Debug)]
pub struct CsrMatrix {
    n_rows: usize,
    n_cols: usize,
    /// Row start offsets, length `n_rows + 1`.
    indptr: Vec<usize>,
    /// Column index of each stored value, length `nnz`.
    indices: Vec<u32>,
    /// Stored values, length `nnz`.
    values: Vec<f32>,
    /// Delta-compressed `u16` mirror of `indices` (DESIGN.md §6.6);
    /// `None` until [`CsrMatrix::build_compact`], and permanently `None`
    /// when the qualifier rejects the matrix (unsorted rows, or escape
    /// blocks would make the stream larger than the `u32` one).
    compact: Option<CompactIndices>,
}

/// Structural equality on the canonical `u32` representation. The compact
/// stream is deliberately excluded: it is derived data (a pure function
/// of `indices` when present), so two logically equal matrices compare
/// equal whether or not either has built it.
impl PartialEq for CsrMatrix {
    fn eq(&self, other: &Self) -> bool {
        self.n_rows == other.n_rows
            && self.n_cols == other.n_cols
            && self.indptr == other.indptr
            && self.indices == other.indices
            && self.values == other.values
    }
}

impl CsrMatrix {
    /// Build from raw parts, validating the invariants.
    pub fn from_parts(
        n_rows: usize,
        n_cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Self {
        assert_eq!(indptr.len(), n_rows + 1, "indptr length");
        assert_eq!(indices.len(), values.len(), "indices/values length");
        assert_eq!(*indptr.last().unwrap_or(&0), indices.len(), "indptr tail");
        debug_assert!(indptr.windows(2).all(|w| w[0] <= w[1]), "indptr monotone");
        debug_assert!(
            indices.iter().all(|&j| (j as usize) < n_cols),
            "column index out of range"
        );
        Self { n_rows, n_cols, indptr, indices, values, compact: None }
    }

    /// Build (or rebuild) the delta-compressed `u16` index mirror.
    /// Called once by `Dataset::new`; a matrix the qualifier rejects
    /// simply stays on the `u32` substrate. Idempotent — the compact
    /// stream is a pure function of `indices`.
    pub fn build_compact(&mut self) {
        self.compact = CompactIndices::build(&self.indptr, &self.indices);
    }

    /// Drop the compact mirror, pinning the matrix to the `u32` substrate
    /// (the benchmark/test baseline; see `Dataset::strip_compact`).
    pub fn clear_compact(&mut self) {
        self.compact = None;
    }

    /// Which index substrate the hot loops will read: `"u16-delta"` after
    /// a successful [`CsrMatrix::build_compact`], else `"u32"`.
    pub fn index_kind(&self) -> &'static str {
        if self.compact.is_some() {
            "u16-delta"
        } else {
            "u32"
        }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// Iterate the nonzeros of row `i` as `(col, value)`.
    #[inline]
    pub fn row(&self, i: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        self.indices[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&j, &v)| (j as usize, v))
    }

    /// Raw slices of row `i` — the canonical `u32` accessor (construction,
    /// I/O, the CSC transpose build). Hot loops should prefer
    /// [`CsrMatrix::row_seg`], which serves the compact stream when built.
    #[inline]
    pub fn row_raw(&self, i: usize) -> (&[u32], &[f32]) {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Row `i` in whichever index representation the matrix carries —
    /// the hot-path accessor the scan kernels consume.
    #[inline]
    pub fn row_seg(&self, i: usize) -> (IndexSeg<'_>, &[f32]) {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        let vals = &self.values[lo..hi];
        match &self.compact {
            Some(c) => (IndexSeg::U16 { words: c.seg_words(i), nnz: hi - lo }, vals),
            None => (IndexSeg::U32(&self.indices[lo..hi]), vals),
        }
    }

    /// Bytes a full sweep of the index structure moves (per-segment byte
    /// counts come from `IndexSeg::index_bytes`, the single source of the
    /// DESIGN.md §6.6 formula).
    pub fn index_bytes_total(&self) -> u64 {
        match &self.compact {
            Some(c) => 2 * c.total_words() as u64,
            None => 4 * self.nnz() as u64,
        }
    }

    /// How a full row sweep splits under `kern`'s dispatcher (DESIGN.md
    /// §6.7): `(direct_segments, scratch_segments, scratch_nnz)` — the
    /// non-empty compact rows taking the fused arm, those decoding to
    /// scratch, and the indices the latter round-trip. `(0, 0, 0)` on the
    /// `u32` substrate. This is the analytic mirror of what the `*_scan`
    /// kernels actually execute (the threshold rule itself lives in
    /// [`ScanKernel::split_segments`]), used by the solvers' per-sweep
    /// accounting; O(n_rows).
    pub fn scan_split(&self, kern: ScanKernel) -> (u64, u64, u64) {
        if self.compact.is_none() {
            return (0, 0, 0);
        }
        kern.split_segments(&self.indptr)
    }

    /// The flat column-index stream (length `nnz`, row-major order) —
    /// used by the parallel CSC transpose build's counting phase.
    #[inline]
    pub fn col_indices(&self) -> &[u32] {
        &self.indices
    }

    /// The flat value stream (length `nnz`, row-major order, parallel to
    /// [`CsrMatrix::col_indices`]) — used by the parallel CSC transpose
    /// build's single-read scatter phase.
    #[inline]
    pub fn values_flat(&self) -> &[f32] {
        &self.values
    }

    /// The row start offsets (length `n_rows + 1`, monotone prefix-nnz) —
    /// lets the scatter phase recover the row index of any flat stream
    /// position without re-reading rows.
    #[inline]
    pub fn row_ptr(&self) -> &[usize] {
        &self.indptr
    }

    /// `out = X · w` (dense `w`, length `n_cols`), accumulated in f64.
    pub fn matvec(&self, w: &[f64], out: &mut [f64]) {
        assert_eq!(w.len(), self.n_cols);
        assert_eq!(out.len(), self.n_rows);
        self.matvec_range(w, 0..self.n_rows, out);
    }

    /// The row-range slice of [`CsrMatrix::matvec`]:
    /// `out[i - rows.start] = x_i · w` for `i ∈ rows`. Allocates a decode
    /// scratch once per call on the compact substrate; pooled-workspace
    /// callers should prefer [`CsrMatrix::matvec_in`].
    pub fn matvec_range(&self, w: &[f64], rows: std::ops::Range<usize>, out: &mut [f64]) {
        self.matvec_range_in(w, rows, out, &mut Vec::new());
    }

    /// `out = X · w` with a caller-provided decode scratch (the solvers'
    /// pooled workspaces use this so repeated runs stay allocation-free
    /// on the compact substrate; the scratch is untouched on `u32`).
    /// Dispatches through the process-wide [`ScanKernel::from_env`];
    /// solvers with an explicit `FwConfig::direct_max_nnz` use
    /// [`CsrMatrix::matvec_scan`].
    pub fn matvec_in(&self, w: &[f64], out: &mut [f64], scratch: &mut Vec<u32>) {
        self.matvec_scan(w, out, scratch, ScanKernel::from_env());
    }

    /// `out = X · w` through an explicit segment-adaptive dispatcher —
    /// the full-control entry point the solvers use so the kernel arm
    /// that runs always matches their per-segment accounting.
    pub fn matvec_scan(&self, w: &[f64], out: &mut [f64], scratch: &mut Vec<u32>, kern: ScanKernel) {
        assert_eq!(w.len(), self.n_cols);
        assert_eq!(out.len(), self.n_rows);
        self.matvec_range_scan(w, 0..self.n_rows, out, scratch, kern);
    }

    /// Scratch-threaded body of [`CsrMatrix::matvec_range`].
    pub fn matvec_range_in(
        &self,
        w: &[f64],
        rows: std::ops::Range<usize>,
        out: &mut [f64],
        scratch: &mut Vec<u32>,
    ) {
        self.matvec_range_scan(w, rows, out, scratch, ScanKernel::from_env());
    }

    /// Dispatcher-threaded body of [`CsrMatrix::matvec_range`]: short
    /// compact rows ride the fused direct-decode arm, long ones reuse the
    /// scratch across the whole range so it stays L1-hot.
    pub fn matvec_range_scan(
        &self,
        w: &[f64],
        rows: std::ops::Range<usize>,
        out: &mut [f64],
        scratch: &mut Vec<u32>,
        kern: ScanKernel,
    ) {
        assert_eq!(out.len(), rows.len());
        for (slot, i) in out.iter_mut().zip(rows) {
            let (seg, vals) = self.row_seg(i);
            *slot = kern.dot(seg, vals, w, scratch);
        }
    }

    /// Block-parallel `out = X · w`: rows are split into `threads`
    /// contiguous nnz-balanced blocks, each writing a disjoint slice of
    /// `out` — no atomics, and (since every row is still summed by one
    /// thread in index order) **bit-identical** to the serial
    /// [`CsrMatrix::matvec`] at any thread count. The
    /// [`super::PAR_MIN_NNZ`] serial-fallback gate lives *here*, not at
    /// call sites: tiny inputs never pay thread-spawn overhead no matter
    /// what thread count the caller asks for.
    pub fn matvec_par(&self, w: &[f64], out: &mut [f64], threads: usize) {
        assert_eq!(w.len(), self.n_cols);
        assert_eq!(out.len(), self.n_rows);
        let threads = if self.nnz() < super::PAR_MIN_NNZ { 1 } else { threads };
        if threads <= 1 || self.n_rows < 2 {
            return self.matvec(w, out);
        }
        let ranges = super::balanced_ranges(&self.indptr, threads);
        std::thread::scope(|s| {
            let mut rest: &mut [f64] = out;
            for r in ranges {
                let (chunk, tail) = rest.split_at_mut(r.len());
                rest = tail;
                s.spawn(move || self.matvec_range(w, r, chunk));
            }
        });
    }

    /// `out += Xᵀ · q` (dense `q`, length `n_rows`), accumulated in f64.
    /// This is the CSR-driven transpose product used by Alg 1's line 6.
    /// Allocates a decode scratch once per call on the compact substrate;
    /// pooled-workspace callers should prefer [`CsrMatrix::matvec_t_add_in`].
    pub fn matvec_t_add(&self, q: &[f64], out: &mut [f64]) {
        self.matvec_t_add_in(q, out, &mut Vec::new());
    }

    /// Scratch-threaded body of [`CsrMatrix::matvec_t_add`], dispatching
    /// through the process-wide [`ScanKernel::from_env`].
    pub fn matvec_t_add_in(&self, q: &[f64], out: &mut [f64], scratch: &mut Vec<u32>) {
        self.matvec_t_add_scan(q, out, scratch, ScanKernel::from_env());
    }

    /// Dispatcher-threaded body of [`CsrMatrix::matvec_t_add`] — the
    /// solvers' entry point (kernel arm matches their accounting).
    pub fn matvec_t_add_scan(
        &self,
        q: &[f64],
        out: &mut [f64],
        scratch: &mut Vec<u32>,
        kern: ScanKernel,
    ) {
        assert_eq!(q.len(), self.n_rows);
        assert_eq!(out.len(), self.n_cols);
        for i in 0..self.n_rows {
            let qi = q[i];
            if qi == 0.0 {
                continue;
            }
            let (seg, vals) = self.row_seg(i);
            kern.axpy(seg, vals, qi, out, scratch);
        }
    }

    /// Dot product of row `i` with dense `w`. A leaf accessor with no
    /// caller scratch, so it has no decode buffer to amortize: short
    /// compact rows ride the fused direct-decode arm (§6.7 — no scratch
    /// needed at all), while rows past the dispatcher threshold stay on
    /// the canonical `u32` stream's prefetched gather rather than paying
    /// an allocation per call. Bit-identical either way.
    #[inline]
    pub fn row_dot(&self, i: usize, w: &[f64]) -> f64 {
        let (seg, vals) = self.row_seg(i);
        match (ScanKernel::from_env().arm(&seg), seg) {
            (SegArm::Direct, IndexSeg::U16 { words, nnz }) => {
                scan::dot_gather_u16(words, nnz, vals, w)
            }
            _ => {
                let (idx, val) = self.row_raw(i);
                scan::dot_gather(idx, val, w)
            }
        }
    }

    /// Densify (tests / the PJRT oracle path only — O(N·D) memory).
    pub fn to_dense_f32(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n_rows * self.n_cols];
        for i in 0..self.n_rows {
            for (j, v) in self.row(i) {
                out[i * self.n_cols + j] = v;
            }
        }
        out
    }

    /// Max absolute feature value (the `B` bound in sensitivity analysis).
    pub fn max_abs_value(&self) -> f64 {
        self.values.iter().fold(0.0f64, |m, &v| m.max(v.abs() as f64))
    }

    /// L2-normalize every row (the standard preprocessing of the paper's
    /// text datasets — RCV1/News20 ship unit-L2 rows). Implies
    /// `‖x‖_∞ ≤ ‖x‖₂ = 1`, satisfying the DP sensitivity bound.
    pub fn normalize_rows_l2(&mut self) {
        for i in 0..self.n_rows {
            let lo = self.indptr[i];
            let hi = self.indptr[i + 1];
            let norm: f64 = self.values[lo..hi]
                .iter()
                .map(|&v| (v as f64) * (v as f64))
                .sum::<f64>()
                .sqrt();
            if norm > 0.0 {
                let inv = (1.0 / norm) as f32;
                for v in &mut self.values[lo..hi] {
                    *v *= inv;
                }
            }
        }
    }

    /// Scale all values so `max_abs_value() == 1` (the paper's sensitivity
    /// bounds assume `‖x‖_∞ ≤ 1`). Returns the scale factor applied.
    pub fn normalize_inf(&mut self) -> f64 {
        let m = self.max_abs_value();
        if m > 0.0 && m != 1.0 {
            let inv = (1.0 / m) as f32;
            for v in &mut self.values {
                *v *= inv;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // [[1,0,2],[0,3,0]]
        CsrMatrix::from_parts(2, 3, vec![0, 2, 3], vec![0, 2, 1], vec![1.0, 2.0, 3.0])
    }

    #[test]
    fn row_iteration() {
        let m = sample();
        let r0: Vec<_> = m.row(0).collect();
        assert_eq!(r0, vec![(0, 1.0), (2, 2.0)]);
        let r1: Vec<_> = m.row(1).collect();
        assert_eq!(r1, vec![(1, 3.0)]);
        assert_eq!(m.row_nnz(0), 2);
    }

    #[test]
    fn matvec_matches_dense() {
        let m = sample();
        let w = [1.0, 2.0, 3.0];
        let mut out = [0.0; 2];
        m.matvec(&w, &mut out);
        assert_eq!(out, [1.0 + 6.0, 6.0]);
    }

    #[test]
    fn matvec_t_matches_dense() {
        let m = sample();
        let q = [2.0, 5.0];
        let mut out = [0.0; 3];
        m.matvec_t_add(&q, &mut out);
        assert_eq!(out, [2.0, 15.0, 4.0]);
    }

    #[test]
    fn row_dot() {
        let m = sample();
        assert_eq!(m.row_dot(0, &[1.0, 1.0, 1.0]), 3.0);
        assert_eq!(m.row_dot(1, &[0.0, 10.0, 0.0]), 30.0);
    }

    #[test]
    fn dense_roundtrip() {
        let m = sample();
        let d = m.to_dense_f32();
        assert_eq!(d, vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn normalize_inf() {
        let mut m = sample();
        let was = m.normalize_inf();
        assert_eq!(was, 3.0);
        assert!((m.max_abs_value() - 1.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "indptr length")]
    fn bad_indptr_panics() {
        CsrMatrix::from_parts(2, 3, vec![0, 1], vec![0], vec![1.0]);
    }

    #[test]
    fn empty_matrix() {
        let m = CsrMatrix::from_parts(0, 0, vec![0], vec![], vec![]);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.max_abs_value(), 0.0);
    }

    fn ragged(n_rows: usize, n_cols: usize) -> CsrMatrix {
        let mut indptr = vec![0usize];
        let mut indices = vec![];
        let mut values = vec![];
        let mut state = 12345u64;
        for i in 0..n_rows {
            let mut nnz_row = (i * 7) % 9; // includes empty rows
            let mut j = (i * 13) % n_cols;
            while nnz_row > 0 && j < n_cols {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                indices.push(j as u32);
                values.push(((state >> 33) as f32 / 2.0_f32.powi(31)) - 1.0);
                j += 1 + (state as usize % 5);
                nnz_row -= 1;
            }
            indptr.push(indices.len());
        }
        CsrMatrix::from_parts(n_rows, n_cols, indptr, indices, values)
    }

    #[test]
    fn compact_kernels_bit_identical_to_u32() {
        let plain = ragged(300, 4000);
        let mut compact = plain.clone();
        compact.build_compact();
        assert_eq!(compact.index_kind(), "u16-delta");
        assert_eq!(plain.index_kind(), "u32");
        assert_eq!(plain, compact, "compact mirror must not affect equality");
        assert!(compact.index_bytes_total() < plain.index_bytes_total());
        let w: Vec<f64> = (0..plain.n_cols()).map(|j| (j as f64 * 0.31).cos()).collect();
        let mut a = vec![0.0f64; plain.n_rows()];
        let mut b = vec![f64::NAN; plain.n_rows()];
        plain.matvec(&w, &mut a);
        compact.matvec(&w, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "matvec diverged");
        }
        let q: Vec<f64> = (0..plain.n_rows()).map(|i| (i as f64 * 0.7 + 0.1).sin()).collect();
        let mut ta = vec![0.0f64; plain.n_cols()];
        let mut tb = vec![0.0f64; plain.n_cols()];
        plain.matvec_t_add(&q, &mut ta);
        compact.matvec_t_add(&q, &mut tb);
        for (x, y) in ta.iter().zip(&tb) {
            assert_eq!(x.to_bits(), y.to_bits(), "matvec_t_add diverged");
        }
        for i in 0..plain.n_rows() {
            assert_eq!(
                plain.row_dot(i, &w).to_bits(),
                compact.row_dot(i, &w).to_bits(),
                "row_dot diverged at row {i}"
            );
        }
    }

    #[test]
    fn matvec_par_above_gate_runs_parallel_and_bit_identical() {
        // nnz ≥ PAR_MIN_NNZ so the in-kernel gate does NOT serialize:
        // this exercises the genuinely threaded path.
        let m = ragged(12_000, 900);
        assert!(m.nnz() >= crate::sparse::PAR_MIN_NNZ, "fixture must clear the gate");
        let w: Vec<f64> = (0..m.n_cols()).map(|j| (j as f64) * 0.37 - 3.0).collect();
        let mut serial = vec![0.0f64; m.n_rows()];
        m.matvec(&w, &mut serial);
        for threads in [2usize, 4, 16] {
            let mut par = vec![f64::NAN; m.n_rows()];
            m.matvec_par(&w, &mut par, threads);
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn matvec_par_bit_identical_to_serial() {
        // A ragged random-ish matrix below PAR_MIN_NNZ: the in-kernel gate
        // serializes, and the output must still be bit-identical.
        let n_rows = 97;
        let n_cols = 53;
        let mut indptr = vec![0usize];
        let mut indices = vec![];
        let mut values = vec![];
        let mut state = 12345u64;
        for i in 0..n_rows {
            let mut nnz_row = (i * 7) % 9; // includes empty rows
            let mut j = (i * 13) % n_cols;
            while nnz_row > 0 && j < n_cols {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                indices.push(j as u32);
                values.push(((state >> 33) as f32 / 2.0_f32.powi(31)) - 1.0);
                j += 1 + (state as usize % 5);
                nnz_row -= 1;
            }
            indptr.push(indices.len());
        }
        let m = CsrMatrix::from_parts(n_rows, n_cols, indptr, indices, values);
        let w: Vec<f64> = (0..n_cols).map(|j| (j as f64) * 0.37 - 3.0).collect();
        let mut serial = vec![0.0f64; n_rows];
        m.matvec(&w, &mut serial);
        for threads in [2usize, 3, 4, 16] {
            let mut par = vec![f64::NAN; n_rows];
            m.matvec_par(&w, &mut par, threads);
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }
}
