//! Compressed Sparse Row matrix: the `X[i,:]` view.
//!
//! Values are `f32` (dataset storage — the paper's datasets are
//! count/tf-idf features), accumulation happens in `f64` everywhere the
//! solvers touch them. Column indices are `u32` (D ≤ 4.29e9 covers the
//! paper's 20.2M-feature KDDA with room to spare) to halve index memory
//! traffic — this matters: the Alg 2 inner loop is memory-bound gathers.

#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    n_rows: usize,
    n_cols: usize,
    /// Row start offsets, length `n_rows + 1`.
    indptr: Vec<usize>,
    /// Column index of each stored value, length `nnz`.
    indices: Vec<u32>,
    /// Stored values, length `nnz`.
    values: Vec<f32>,
}

impl CsrMatrix {
    /// Build from raw parts, validating the invariants.
    pub fn from_parts(
        n_rows: usize,
        n_cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Self {
        assert_eq!(indptr.len(), n_rows + 1, "indptr length");
        assert_eq!(indices.len(), values.len(), "indices/values length");
        assert_eq!(*indptr.last().unwrap_or(&0), indices.len(), "indptr tail");
        debug_assert!(indptr.windows(2).all(|w| w[0] <= w[1]), "indptr monotone");
        debug_assert!(
            indices.iter().all(|&j| (j as usize) < n_cols),
            "column index out of range"
        );
        Self { n_rows, n_cols, indptr, indices, values }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// Iterate the nonzeros of row `i` as `(col, value)`.
    #[inline]
    pub fn row(&self, i: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        self.indices[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&j, &v)| (j as usize, v))
    }

    /// Raw slices of row `i` — the hot-path accessor (no per-element zip
    /// overhead; lets the caller keep the gather loop tight).
    #[inline]
    pub fn row_raw(&self, i: usize) -> (&[u32], &[f32]) {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// The flat column-index stream (length `nnz`, row-major order) —
    /// used by the parallel CSC transpose build's counting phase.
    #[inline]
    pub fn col_indices(&self) -> &[u32] {
        &self.indices
    }

    /// The flat value stream (length `nnz`, row-major order, parallel to
    /// [`CsrMatrix::col_indices`]) — used by the parallel CSC transpose
    /// build's single-read scatter phase.
    #[inline]
    pub fn values_flat(&self) -> &[f32] {
        &self.values
    }

    /// The row start offsets (length `n_rows + 1`, monotone prefix-nnz) —
    /// lets the scatter phase recover the row index of any flat stream
    /// position without re-reading rows.
    #[inline]
    pub fn row_ptr(&self) -> &[usize] {
        &self.indptr
    }

    /// `out = X · w` (dense `w`, length `n_cols`), accumulated in f64.
    pub fn matvec(&self, w: &[f64], out: &mut [f64]) {
        assert_eq!(w.len(), self.n_cols);
        assert_eq!(out.len(), self.n_rows);
        self.matvec_range(w, 0..self.n_rows, out);
    }

    /// The row-range slice of [`CsrMatrix::matvec`]:
    /// `out[i - rows.start] = x_i · w` for `i ∈ rows`.
    pub fn matvec_range(&self, w: &[f64], rows: std::ops::Range<usize>, out: &mut [f64]) {
        assert_eq!(out.len(), rows.len());
        for (slot, i) in out.iter_mut().zip(rows) {
            let (idx, val) = self.row_raw(i);
            let mut acc = 0.0f64;
            for (&j, &v) in idx.iter().zip(val) {
                acc += v as f64 * w[j as usize];
            }
            *slot = acc;
        }
    }

    /// Block-parallel `out = X · w`: rows are split into `threads`
    /// contiguous nnz-balanced blocks, each writing a disjoint slice of
    /// `out` — no atomics, and (since every row is still summed by one
    /// thread in index order) **bit-identical** to the serial
    /// [`CsrMatrix::matvec`] at any thread count.
    pub fn matvec_par(&self, w: &[f64], out: &mut [f64], threads: usize) {
        assert_eq!(w.len(), self.n_cols);
        assert_eq!(out.len(), self.n_rows);
        if threads <= 1 || self.n_rows < 2 {
            return self.matvec(w, out);
        }
        let ranges = super::balanced_ranges(&self.indptr, threads);
        std::thread::scope(|s| {
            let mut rest: &mut [f64] = out;
            for r in ranges {
                let (chunk, tail) = rest.split_at_mut(r.len());
                rest = tail;
                s.spawn(move || self.matvec_range(w, r, chunk));
            }
        });
    }

    /// `out += Xᵀ · q` (dense `q`, length `n_rows`), accumulated in f64.
    /// This is the CSR-driven transpose product used by Alg 1's line 6.
    pub fn matvec_t_add(&self, q: &[f64], out: &mut [f64]) {
        assert_eq!(q.len(), self.n_rows);
        assert_eq!(out.len(), self.n_cols);
        for i in 0..self.n_rows {
            let qi = q[i];
            if qi == 0.0 {
                continue;
            }
            let (idx, val) = self.row_raw(i);
            for (&j, &v) in idx.iter().zip(val) {
                out[j as usize] += v as f64 * qi;
            }
        }
    }

    /// Dot product of row `i` with dense `w`.
    #[inline]
    pub fn row_dot(&self, i: usize, w: &[f64]) -> f64 {
        let (idx, val) = self.row_raw(i);
        let mut acc = 0.0f64;
        for (&j, &v) in idx.iter().zip(val) {
            acc += v as f64 * w[j as usize];
        }
        acc
    }

    /// Densify (tests / the PJRT oracle path only — O(N·D) memory).
    pub fn to_dense_f32(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n_rows * self.n_cols];
        for i in 0..self.n_rows {
            for (j, v) in self.row(i) {
                out[i * self.n_cols + j] = v;
            }
        }
        out
    }

    /// Max absolute feature value (the `B` bound in sensitivity analysis).
    pub fn max_abs_value(&self) -> f64 {
        self.values.iter().fold(0.0f64, |m, &v| m.max(v.abs() as f64))
    }

    /// L2-normalize every row (the standard preprocessing of the paper's
    /// text datasets — RCV1/News20 ship unit-L2 rows). Implies
    /// `‖x‖_∞ ≤ ‖x‖₂ = 1`, satisfying the DP sensitivity bound.
    pub fn normalize_rows_l2(&mut self) {
        for i in 0..self.n_rows {
            let lo = self.indptr[i];
            let hi = self.indptr[i + 1];
            let norm: f64 = self.values[lo..hi]
                .iter()
                .map(|&v| (v as f64) * (v as f64))
                .sum::<f64>()
                .sqrt();
            if norm > 0.0 {
                let inv = (1.0 / norm) as f32;
                for v in &mut self.values[lo..hi] {
                    *v *= inv;
                }
            }
        }
    }

    /// Scale all values so `max_abs_value() == 1` (the paper's sensitivity
    /// bounds assume `‖x‖_∞ ≤ 1`). Returns the scale factor applied.
    pub fn normalize_inf(&mut self) -> f64 {
        let m = self.max_abs_value();
        if m > 0.0 && m != 1.0 {
            let inv = (1.0 / m) as f32;
            for v in &mut self.values {
                *v *= inv;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // [[1,0,2],[0,3,0]]
        CsrMatrix::from_parts(2, 3, vec![0, 2, 3], vec![0, 2, 1], vec![1.0, 2.0, 3.0])
    }

    #[test]
    fn row_iteration() {
        let m = sample();
        let r0: Vec<_> = m.row(0).collect();
        assert_eq!(r0, vec![(0, 1.0), (2, 2.0)]);
        let r1: Vec<_> = m.row(1).collect();
        assert_eq!(r1, vec![(1, 3.0)]);
        assert_eq!(m.row_nnz(0), 2);
    }

    #[test]
    fn matvec_matches_dense() {
        let m = sample();
        let w = [1.0, 2.0, 3.0];
        let mut out = [0.0; 2];
        m.matvec(&w, &mut out);
        assert_eq!(out, [1.0 + 6.0, 6.0]);
    }

    #[test]
    fn matvec_t_matches_dense() {
        let m = sample();
        let q = [2.0, 5.0];
        let mut out = [0.0; 3];
        m.matvec_t_add(&q, &mut out);
        assert_eq!(out, [2.0, 15.0, 4.0]);
    }

    #[test]
    fn row_dot() {
        let m = sample();
        assert_eq!(m.row_dot(0, &[1.0, 1.0, 1.0]), 3.0);
        assert_eq!(m.row_dot(1, &[0.0, 10.0, 0.0]), 30.0);
    }

    #[test]
    fn dense_roundtrip() {
        let m = sample();
        let d = m.to_dense_f32();
        assert_eq!(d, vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn normalize_inf() {
        let mut m = sample();
        let was = m.normalize_inf();
        assert_eq!(was, 3.0);
        assert!((m.max_abs_value() - 1.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "indptr length")]
    fn bad_indptr_panics() {
        CsrMatrix::from_parts(2, 3, vec![0, 1], vec![0], vec![1.0]);
    }

    #[test]
    fn empty_matrix() {
        let m = CsrMatrix::from_parts(0, 0, vec![0], vec![], vec![]);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.max_abs_value(), 0.0);
    }

    #[test]
    fn matvec_par_bit_identical_to_serial() {
        // A ragged random-ish matrix large enough that blocks are nonempty
        // for several thread counts.
        let n_rows = 97;
        let n_cols = 53;
        let mut indptr = vec![0usize];
        let mut indices = vec![];
        let mut values = vec![];
        let mut state = 12345u64;
        for i in 0..n_rows {
            let mut nnz_row = (i * 7) % 9; // includes empty rows
            let mut j = (i * 13) % n_cols;
            while nnz_row > 0 && j < n_cols {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                indices.push(j as u32);
                values.push(((state >> 33) as f32 / 2.0_f32.powi(31)) - 1.0);
                j += 1 + (state as usize % 5);
                nnz_row -= 1;
            }
            indptr.push(indices.len());
        }
        let m = CsrMatrix::from_parts(n_rows, n_cols, indptr, indices, values);
        let w: Vec<f64> = (0..n_cols).map(|j| (j as f64) * 0.37 - 3.0).collect();
        let mut serial = vec![0.0f64; n_rows];
        m.matvec(&w, &mut serial);
        for threads in [2usize, 3, 4, 16] {
            let mut par = vec![f64::NAN; n_rows];
            m.matvec_par(&w, &mut par, threads);
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }
}
