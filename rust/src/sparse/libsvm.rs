//! LIBSVM / SVMlight text format reader & writer.
//!
//! The paper's five datasets (RCV1, News20, URL, Web, KDDA) are all
//! distributed in this format: one row per line,
//! `label idx:val idx:val ...` with 1-based feature indices. We accept
//! labels in {0,1}, {-1,+1} (mapped to {0,1}) and arbitrary reals mapped by
//! sign, `#` comments, and blank lines. A real downloaded dataset drops
//! straight into the experiment harness; the synthetic generators write the
//! same format so the two paths are interchangeable.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::coo::CooBuilder;
use super::Dataset;

/// Parse LIBSVM text from any reader.
pub fn read<R: BufRead>(reader: R, name: &str) -> Result<Dataset> {
    let mut coo = CooBuilder::new(0, 0);
    let mut labels: Vec<f32> = Vec::new();
    let mut declared_dims: Option<(usize, usize)> = None;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.with_context(|| format!("read error at line {}", lineno + 1))?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            // our writer records logical dimensions (trailing all-zero
            // columns are invisible to plain LIBSVM)
            if let Some(rest) = line.strip_prefix("# dpfw dims ") {
                let mut it = rest.split_ascii_whitespace();
                if let (Some(n), Some(d)) = (it.next(), it.next()) {
                    declared_dims = Some((
                        n.parse().context("bad dims header")?,
                        d.parse().context("bad dims header")?,
                    ));
                }
            }
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label_tok = parts.next().unwrap();
        let label: f64 = label_tok
            .parse()
            .with_context(|| format!("bad label {label_tok:?} at line {}", lineno + 1))?;
        if !label.is_finite() {
            bail!("non-finite label {label} at line {}", lineno + 1);
        }
        let row = coo.add_row();
        labels.push(if label > 0.0 { 1.0 } else { 0.0 });
        let mut prev_idx: i64 = -1;
        for tok in parts {
            if tok.starts_with('#') {
                break; // trailing comment
            }
            let (idx_s, val_s) = tok
                .split_once(':')
                .with_context(|| format!("bad pair {tok:?} at line {}", lineno + 1))?;
            let idx: usize = idx_s
                .parse()
                .with_context(|| format!("bad index {idx_s:?} at line {}", lineno + 1))?;
            if idx == 0 {
                bail!("feature index 0 at line {} (LIBSVM is 1-based)", lineno + 1);
            }
            if (idx as i64) <= prev_idx {
                bail!("non-increasing feature index at line {}", lineno + 1);
            }
            prev_idx = idx as i64;
            let val: f32 = val_s
                .parse()
                .with_context(|| format!("bad value {val_s:?} at line {}", lineno + 1))?;
            if !val.is_finite() {
                // a NaN/Inf would silently poison every downstream dot
                // product and DP score; refuse the file with a location
                bail!("non-finite value {val_s:?} at line {}", lineno + 1);
            }
            coo.push(row, idx - 1, val);
        }
    }
    if labels.is_empty() {
        bail!("no rows parsed");
    }
    if let Some((_, d)) = declared_dims {
        // rows always equal the parsed line count; only the column count
        // can be under-inferred (trailing all-zero columns)
        if d >= coo.n_cols() {
            coo.set_shape(coo.n_rows(), d);
        }
    }
    Dataset::try_new(coo.to_csr(), labels, name)
        .map_err(|e| anyhow::anyhow!("invalid dataset: {e}"))
}

/// Read a LIBSVM file from disk.
pub fn read_file(path: impl AsRef<Path>) -> Result<Dataset> {
    let path = path.as_ref();
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dataset".into());
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    read(BufReader::new(f), &name)
}

/// Write a dataset in LIBSVM format (1-based indices, labels 0/1).
pub fn write_file(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# dpfw dims {} {}", ds.n_rows(), ds.n_cols())?;
    for i in 0..ds.n_rows() {
        write!(w, "{}", ds.labels[i] as i32)?;
        for (j, v) in ds.csr.row(i) {
            write!(w, " {}:{}", j + 1, v)?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_basic() {
        let text = "1 1:0.5 3:2.0\n-1 2:1.5\n";
        let ds = read(Cursor::new(text), "t").unwrap();
        assert_eq!(ds.n_rows(), 2);
        assert_eq!(ds.n_cols(), 3);
        assert_eq!(ds.labels, vec![1.0, 0.0]);
        assert_eq!(ds.csr.row(0).collect::<Vec<_>>(), vec![(0, 0.5), (2, 2.0)]);
        assert_eq!(ds.csr.row(1).collect::<Vec<_>>(), vec![(1, 1.5)]);
    }

    #[test]
    fn handles_comments_and_blanks() {
        let text = "# header\n\n1 1:1.0\n0 2:2.0 # trailing\n";
        let ds = read(Cursor::new(text), "t").unwrap();
        assert_eq!(ds.n_rows(), 2);
        assert_eq!(ds.labels, vec![1.0, 0.0]);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(read(Cursor::new("1 0:1.0\n"), "t").is_err());
    }

    #[test]
    fn rejects_unsorted_indices() {
        assert!(read(Cursor::new("1 3:1.0 2:1.0\n"), "t").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(read(Cursor::new("abc 1:1.0\n"), "t").is_err());
        assert!(read(Cursor::new("1 1-1.0\n"), "t").is_err());
        assert!(read(Cursor::new(""), "t").is_err());
    }

    #[test]
    fn rejects_non_finite() {
        // Rust's f32/f64 parsers happily accept "nan"/"inf" — the explicit
        // finiteness checks are what turns these into typed refusals.
        assert!(read(Cursor::new("1 1:nan\n"), "t").is_err());
        assert!(read(Cursor::new("1 1:inf\n"), "t").is_err());
        assert!(read(Cursor::new("1 1:-inf\n"), "t").is_err());
        assert!(read(Cursor::new("nan 1:1.0\n"), "t").is_err());
        assert!(read(Cursor::new("inf 1:1.0\n"), "t").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = "1 1:0.5 3:2\n0 2:1.5\n1 1:1 2:1 3:1\n";
        let ds = read(Cursor::new(text), "t").unwrap();
        let tmp = std::env::temp_dir().join("dpfw_libsvm_roundtrip.svm");
        write_file(&ds, &tmp).unwrap();
        let ds2 = read_file(&tmp).unwrap();
        std::fs::remove_file(&tmp).ok();
        assert_eq!(ds.labels, ds2.labels);
        assert_eq!(ds.csr, ds2.csr);
    }
}
