//! COO (triplet) builder — the mutable construction format.
//!
//! Generators and parsers append `(row, col, value)` triplets, then convert
//! once to CSR. Duplicate `(row, col)` entries are summed on conversion
//! (scipy semantics), entries within a row come out column-sorted.

use super::csr::CsrMatrix;

#[derive(Clone, Debug, Default)]
pub struct CooBuilder {
    n_rows: usize,
    n_cols: usize,
    rows: Vec<u32>,
    cols: Vec<u32>,
    vals: Vec<f32>,
}

impl CooBuilder {
    pub fn new(n_rows: usize, n_cols: usize) -> Self {
        Self { n_rows, n_cols, rows: vec![], cols: vec![], vals: vec![] }
    }

    /// Append a new empty row, returning its index.
    pub fn add_row(&mut self) -> usize {
        self.n_rows += 1;
        self.n_rows - 1
    }

    /// Push one triplet. Grows the matrix if `row`/`col` exceed the current
    /// bounds (parsers discover dimensions as they read).
    pub fn push(&mut self, row: usize, col: usize, val: f32) {
        if val == 0.0 {
            return; // never store explicit zeros
        }
        self.n_rows = self.n_rows.max(row + 1);
        self.n_cols = self.n_cols.max(col + 1);
        self.rows.push(row as u32);
        self.cols.push(col as u32);
        self.vals.push(val);
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Force the logical dimensions (e.g. LIBSVM headers that declare more
    /// columns than appear in the data).
    pub fn set_shape(&mut self, n_rows: usize, n_cols: usize) {
        assert!(n_rows >= self.n_rows && n_cols >= self.n_cols);
        self.n_rows = n_rows;
        self.n_cols = n_cols;
    }

    /// Convert to CSR: counting sort by row, then per-row sort by column,
    /// summing duplicates. O(nnz log S_c + N + nnz).
    pub fn to_csr(&self) -> CsrMatrix {
        let mut indptr = vec![0usize; self.n_rows + 1];
        for &r in &self.rows {
            indptr[r as usize + 1] += 1;
        }
        for i in 0..self.n_rows {
            indptr[i + 1] += indptr[i];
        }
        let nnz = self.vals.len();
        let mut cursor = indptr.clone();
        let mut cols = vec![0u32; nnz];
        let mut vals = vec![0.0f32; nnz];
        for k in 0..nnz {
            let r = self.rows[k] as usize;
            let p = cursor[r];
            cols[p] = self.cols[k];
            vals[p] = self.vals[k];
            cursor[r] = p + 1;
        }
        // per-row: sort by column, merge duplicates
        let mut out_indptr = vec![0usize; self.n_rows + 1];
        let mut out_cols: Vec<u32> = Vec::with_capacity(nnz);
        let mut out_vals: Vec<f32> = Vec::with_capacity(nnz);
        let mut scratch: Vec<(u32, f32)> = Vec::new();
        for i in 0..self.n_rows {
            scratch.clear();
            scratch.extend(
                cols[indptr[i]..indptr[i + 1]]
                    .iter()
                    .copied()
                    .zip(vals[indptr[i]..indptr[i + 1]].iter().copied()),
            );
            scratch.sort_unstable_by_key(|&(c, _)| c);
            let mut k = 0;
            while k < scratch.len() {
                let (c, mut v) = scratch[k];
                k += 1;
                while k < scratch.len() && scratch[k].0 == c {
                    v += scratch[k].1;
                    k += 1;
                }
                if v != 0.0 {
                    out_cols.push(c);
                    out_vals.push(v);
                }
            }
            out_indptr[i + 1] = out_cols.len();
        }
        CsrMatrix::from_parts(self.n_rows, self.n_cols, out_indptr, out_cols, out_vals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_sorted_csr() {
        let mut b = CooBuilder::new(2, 3);
        b.push(1, 1, 3.0);
        b.push(0, 2, 2.0);
        b.push(0, 0, 1.0);
        let m = b.to_csr();
        assert_eq!(m.row(0).collect::<Vec<_>>(), vec![(0, 1.0), (2, 2.0)]);
        assert_eq!(m.row(1).collect::<Vec<_>>(), vec![(1, 3.0)]);
    }

    #[test]
    fn sums_duplicates() {
        let mut b = CooBuilder::new(1, 2);
        b.push(0, 1, 2.0);
        b.push(0, 1, 3.0);
        let m = b.to_csr();
        assert_eq!(m.row(0).collect::<Vec<_>>(), vec![(1, 5.0)]);
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn drops_zeros() {
        let mut b = CooBuilder::new(1, 2);
        b.push(0, 0, 0.0);
        b.push(0, 1, 1.0);
        b.push(0, 1, -1.0); // cancels to zero
        let m = b.to_csr();
        assert_eq!(m.nnz(), 0);
    }

    #[test]
    fn grows_shape() {
        let mut b = CooBuilder::new(0, 0);
        b.push(4, 7, 1.0);
        assert_eq!(b.n_rows(), 5);
        assert_eq!(b.n_cols(), 8);
        let m = b.to_csr();
        assert_eq!(m.n_rows(), 5);
        assert_eq!(m.n_cols(), 8);
    }

    #[test]
    fn set_shape_pads() {
        let mut b = CooBuilder::new(1, 1);
        b.push(0, 0, 1.0);
        b.set_shape(3, 5);
        let m = b.to_csr();
        assert_eq!(m.n_rows(), 3);
        assert_eq!(m.n_cols(), 5);
        assert_eq!(m.row_nnz(2), 0);
    }
}
