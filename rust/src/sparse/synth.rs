//! Synthetic sparse dataset generator shaped like the paper's evaluation
//! datasets (Table 2).
//!
//! We do not have the real RCV1/News20/URL/Web/KDDA files in this offline
//! environment, so each preset reproduces the *statistics that drive the
//! paper's results*: row count N, feature count D, average row sparsity
//! S_c, Zipfian column-popularity (text features), the number of
//! informative features, and — crucial for the paper's §4.2 URL analysis —
//! a block of **dense informative columns** (URL has ~200 dense features;
//! when ε is large those get selected often and kill the sparse-update
//! advantage, which is exactly the ε=1 vs ε=0.1 speedup jump in Table 3).
//!
//! Labels come from a planted sparse logistic model over the informative
//! features, so accuracy/AUC are meaningful and the non-private solver has
//! a real signal to converge to. A real LIBSVM file can replace any preset
//! via [`crate::sparse::libsvm::read_file`].

use crate::rng::dist;
use crate::rng::Xoshiro256pp;

use super::coo::CooBuilder;
use super::Dataset;

/// The five evaluation datasets from the paper's Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DatasetPreset {
    Rcv1,
    News20,
    Url,
    Web,
    Kdda,
}

impl DatasetPreset {
    pub const ALL: [DatasetPreset; 5] = [
        DatasetPreset::Rcv1,
        DatasetPreset::News20,
        DatasetPreset::Url,
        DatasetPreset::Web,
        DatasetPreset::Kdda,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            DatasetPreset::Rcv1 => "rcv1",
            DatasetPreset::News20 => "news20",
            DatasetPreset::Url => "url",
            DatasetPreset::Web => "web",
            DatasetPreset::Kdda => "kdda",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|p| p.name() == s)
    }
}

/// Generator parameters. Construct via [`SynthConfig::preset`] (+
/// [`SynthConfig::scale`]) or fill fields directly for custom studies.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub name: String,
    /// Rows (paper: N).
    pub n_rows: usize,
    /// Features (paper: D).
    pub n_cols: usize,
    /// Average nonzeros per row over the *sparse* features (paper: S_c).
    pub avg_row_nnz: f64,
    /// Zipf exponent for sparse-column popularity (text data ≈ 1.1–1.3).
    pub zipf_exponent: f64,
    /// Number of informative sparse features (carry label signal).
    pub n_informative: usize,
    /// Number of *dense* informative columns (URL-style); each appears in
    /// every row. 0 for pure-text datasets.
    pub n_dense: usize,
    /// Label noise: probability of flipping the planted label.
    pub label_noise: f64,
    /// Prepend a constant bias column (index 0, value 1 in every row, à la
    /// liblinear's `--bias`). The planted labels are mean-centered, so an
    /// intercept-free model can rank (high AUC) but not threshold (chance
    /// accuracy); the bias column lets the L1-ball model learn the
    /// intercept. Defaults to `true` in presets.
    pub bias_col: bool,
}

impl SynthConfig {
    /// Full-size parameters per the paper's Table 2 (S_c values from the
    /// public LIBSVM dataset statistics; URL's 200-dense-feature structure
    /// from the paper's §4.2 discussion).
    pub fn preset(p: DatasetPreset) -> Self {
        let (n_rows, n_cols, avg_row_nnz, n_dense) = match p {
            DatasetPreset::Rcv1 => (20_242, 47_236, 76.0, 0),
            DatasetPreset::News20 => (19_996, 1_355_191, 455.0, 0),
            DatasetPreset::Url => (2_396_130, 3_231_961, 115.0, 200),
            DatasetPreset::Web => (350_000, 16_609_143, 3_730.0, 0),
            DatasetPreset::Kdda => (8_407_752, 20_216_830, 36.0, 0),
        };
        Self {
            name: p.name().to_string(),
            n_rows,
            n_cols,
            avg_row_nnz,
            zipf_exponent: 1.2,
            // A compact informative set keeps each signal feature at a few
            // percent row-presence (sparse, but learnable within a few
            // thousand FW iterations) — mirroring how few topical terms
            // drive linear text classifiers.
            n_informative: (n_cols / 100).clamp(16, 48),
            n_dense,
            label_noise: 0.05,
            bias_col: true,
        }
    }

    /// Scale N and D by `f` (dense block and informative count scale too,
    /// with floors so tiny configs stay meaningful). Keeps S_c, so density
    /// *rises* as D shrinks — call [`SynthConfig::scale_nnz`] too when the
    /// paper-faithful density matters.
    pub fn scale(mut self, f: f64) -> Self {
        assert!(f > 0.0);
        self.n_rows = ((self.n_rows as f64 * f) as usize).max(64);
        self.n_cols = ((self.n_cols as f64 * f) as usize).max(128);
        self.avg_row_nnz = self.avg_row_nnz.min(self.n_cols as f64 / 4.0);
        self.n_informative = self
            .n_informative
            .min(self.n_cols / 8)
            .max(8);
        if self.n_dense > 0 {
            self.n_dense = ((self.n_dense as f64 * f) as usize).clamp(8, self.n_cols / 4);
        }
        self
    }

    /// Also scale the per-row nonzero count (preserves density rather than
    /// S_c).
    pub fn scale_nnz(mut self, f: f64) -> Self {
        self.avg_row_nnz = (self.avg_row_nnz * f).max(2.0);
        self
    }

    /// Generate the dataset deterministically from `seed`.
    pub fn generate(&self, seed: u64) -> Dataset {
        let mut rng = Xoshiro256pp::seeded(seed ^ 0xD1FF_5EED);
        let d = self.n_cols;
        let n_bias = usize::from(self.bias_col);
        // layout: [bias?][dense block][sparse block]
        let n_dense = (self.n_dense + n_bias).min(d);
        let n_sparse = d - n_dense;
        // Planted model: dense columns all informative; a Zipf-head subset
        // of sparse columns informative. Weights ±|N(0,1)|·2.
        let n_inf_sparse = self.n_informative.min(n_sparse);
        let mut w_true = vec![0.0f64; d];
        for w in w_true.iter_mut().take(n_dense).skip(n_bias) {
            *w = 2.0 * dist::normal(&mut rng);
        }
        // Informative sparse features sit in the Zipf *tail*: topical,
        // specific terms — their occurrences come (almost) only from the
        // class-conditional topical draws below, giving each a clean
        // label correlation. (Head placement would bury the signal under
        // label-independent background hits of the same columns, and
        // near-dense informative columns would also erase the sparse-
        // update advantage — the URL dataset's dense informative block is
        // modeled explicitly by `n_dense` instead.)
        let lo = (n_sparse / 2).min(n_sparse.saturating_sub(1));
        let hi = (3 * n_sparse / 4).max(lo + n_inf_sparse).min(n_sparse);
        let stride = ((hi - lo) / n_inf_sparse.max(1)).max(1);
        for k in 0..n_inf_sparse {
            let j = n_dense + lo + k * stride;
            if j < d {
                w_true[j] = 3.0 * dist::normal(&mut rng);
            }
        }

        // Generation is topic-model-style: draw the class first, then emit
        // class-consistent topical tokens plus Zipf background noise. This
        // mirrors real text corpora — every document carries a few terms
        // that genuinely indicate its topic — and gives informative
        // features strong per-feature label correlation, which is what
        // makes the argmax-gradient selection of Frank-Wolfe find signal
        // instead of the √N random-walk gradients of frequent noise words.
        //
        // Values are tf·idf (stop-word heads get idf ≈ 0, specific tail
        // terms idf ≈ ln N) and rows are L2-normalized at the end — the
        // exact preprocessing of the real RCV1/News20 releases. Without
        // idf, duplicate-merged head tokens in long-row datasets (Web's
        // 3.7k tokens/row) dwarf everything and no linear model trains.
        let zipf_z: f64 = (1..=n_sparse.max(1))
            .map(|r| (r as f64).powf(-self.zipf_exponent))
            .sum();
        let target_len = self.avg_row_nnz.min(n_sparse as f64).max(1.0);
        let idf = |rank: usize| -> f64 {
            // expected document frequency of this rank under the Zipf draw
            let p_tok = (rank as f64 + 1.0).powf(-self.zipf_exponent) / zipf_z;
            let df = (self.n_rows as f64 * (target_len * p_tok).min(1.0)).max(1.0);
            (1.0 + self.n_rows as f64 / df).ln()
        };
        let mut coo = CooBuilder::new(0, 0);
        coo.set_shape(0, d);
        let mut labels = Vec::with_capacity(self.n_rows);
        let inf_index = |pick: usize| n_dense + lo + pick * stride;
        for _ in 0..self.n_rows {
            let row = coo.add_row();
            let y = rng.next_below(2) as f64; // balanced classes
            let mut dense_dot = 0.0f64;
            if n_bias > 0 {
                coo.push(row, 0, 1.0); // intercept feature
            }
            // dense block: class-shifted normal values (URL's informative
            // dense features), weight sign dictates the shift direction
            for j in n_bias..n_dense {
                let shift = 0.75 * (2.0 * y - 1.0) * crate::fw::sign_pub(w_true[j]);
                let v = (dist::normal(&mut rng) + shift) as f32;
                coo.push(row, j, v);
                dense_dot += v as f64 * w_true[j];
            }
            if n_sparse > 0 {
                // background: heavy-tailed row length of Zipf noise tokens
                let target = self.avg_row_nnz.min(n_sparse as f64).max(1.0);
                let len = (target / 2.0 + dist::exponential(&mut rng, 2.0 / target))
                    .round()
                    .clamp(1.0, n_sparse as f64) as usize;
                for _ in 0..len {
                    let rank = dist::zipf_like(&mut rng, n_sparse, self.zipf_exponent);
                    let j = n_dense + rank;
                    // tf · idf magnitude
                    let v = ((0.1 + dist::exponential(&mut rng, 2.0)) * idf(rank)) as f32;
                    coo.push(row, j, v);
                }
                // topical tokens: 2-4 draws from the informative set,
                // biased (90/10) toward features whose planted sign
                // matches the class. Values are tf-idf-like: rare topical
                // terms carry high idf, so their magnitudes are several
                // times the background's — this is what makes the signal
                // visible to argmax-gradient selection at scaled-down N.
                if n_inf_sparse > 0 {
                    // topical token count scales with document length
                    // (long documents repeat their topic vocabulary), so
                    // the per-row signal survives L2 normalization even
                    // for Web-like 3.7k-token rows
                    let k = (2 + rng.next_below(3) as usize + len / 64).min(48);
                    for _ in 0..k {
                        let mut pick = rng.next_below(n_inf_sparse as u64) as usize;
                        let want_positive = (y > 0.5) == (rng.next_f64() < 0.9);
                        // resample a few times for a sign-consistent token
                        for _ in 0..8 {
                            let j = inf_index(pick);
                            if j < d && (w_true[j] > 0.0) == want_positive {
                                break;
                            }
                            pick = rng.next_below(n_inf_sparse as u64) as usize;
                        }
                        let j = inf_index(pick);
                        if j < d {
                            let v = ((0.5 + dist::exponential(&mut rng, 1.0))
                                * idf(lo + pick * stride))
                                as f32;
                            coo.push(row, j, v);
                        }
                    }
                }
            }
            // label: class, flipped by noise; dense-only datasets inherit
            // the class through the shifted dense block (dense_dot unused
            // otherwise — the class itself is the ground truth)
            let _ = dense_dot;
            let mut label = y;
            if rng.next_f64() < self.label_noise {
                label = 1.0 - label;
            }
            labels.push(label as f32);
        }
        let mut csr = coo.to_csr();
        // Unit-L2 rows (real-dataset preprocessing); implies ‖x‖_∞ ≤ 1,
        // which is what the paper's sensitivity analysis assumes.
        csr.normalize_rows_l2();
        Dataset::new(csr, labels, self.name.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let cfg = SynthConfig::preset(DatasetPreset::Rcv1).scale(0.01);
        let a = cfg.generate(7);
        let b = cfg.generate(7);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.csr, b.csr);
        let c = cfg.generate(8);
        assert!(a.labels != c.labels || a.csr != c.csr);
    }

    #[test]
    fn respects_shape_and_sparsity() {
        let cfg = SynthConfig::preset(DatasetPreset::Rcv1).scale(0.02);
        let ds = cfg.generate(1);
        assert_eq!(ds.n_rows(), cfg.n_rows);
        assert_eq!(ds.n_cols(), cfg.n_cols);
        // S_c in the right ballpark (duplicates merge, so some shrink)
        let s_c = ds.avg_row_nnz();
        assert!(
            s_c > cfg.avg_row_nnz * 0.3 && s_c < cfg.avg_row_nnz * 1.7,
            "S_c={s_c} target={}",
            cfg.avg_row_nnz
        );
        assert!(ds.density() < 0.2);
    }

    #[test]
    fn url_preset_has_dense_block() {
        let cfg = SynthConfig::preset(DatasetPreset::Url).scale(0.0005);
        let ds = cfg.generate(3);
        // every dense column occurs in (almost) every row
        for j in 0..cfg.n_dense.min(4) {
            assert!(
                ds.csc.col_nnz(j) as f64 > 0.9 * ds.n_rows() as f64,
                "dense col {j} has {} of {} rows",
                ds.csc.col_nnz(j),
                ds.n_rows()
            );
        }
        // sparse tail columns are rare
        let tail = ds.n_cols() - 1;
        assert!(ds.csc.col_nnz(tail) < ds.n_rows() / 10);
    }

    #[test]
    fn labels_are_binary_and_balanced_ish() {
        let ds = SynthConfig::preset(DatasetPreset::News20).scale(0.01).generate(5);
        assert!(ds.labels.iter().all(|&y| y == 0.0 || y == 1.0));
        let pos: f64 = ds.labels.iter().map(|&y| y as f64).sum::<f64>() / ds.labels.len() as f64;
        assert!(pos > 0.15 && pos < 0.85, "pos rate {pos}");
    }

    #[test]
    fn values_are_inf_normalized() {
        let ds = SynthConfig::preset(DatasetPreset::Rcv1).scale(0.01).generate(9);
        assert!(ds.csr.max_abs_value() <= 1.0 + 1e-6);
    }

    #[test]
    fn zipf_makes_popularity_skew() {
        let ds = SynthConfig::preset(DatasetPreset::Rcv1).scale(0.02).generate(11);
        // head sparse column should be much more popular than the median
        let head = ds.csc.col_nnz(0);
        let mid = ds.csc.col_nnz(ds.n_cols() / 2);
        assert!(head > 5 * (mid + 1), "head={head} mid={mid}");
    }

    #[test]
    fn preset_roundtrip_names() {
        for p in DatasetPreset::ALL {
            assert_eq!(DatasetPreset::from_name(p.name()), Some(p));
        }
        assert_eq!(DatasetPreset::from_name("nope"), None);
    }
}
