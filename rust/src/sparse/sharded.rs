//! Row-sharded dataset substrate (DESIGN.md §6.8).
//!
//! [`ShardedDataset`] partitions a [`Dataset`] into `P` contiguous,
//! nnz-balanced row ranges. Each [`Shard`] owns *its own* CSR and CSC
//! views of its row slab — including their compact `u16-delta` index
//! mirrors — so a shard's hot-loop scans touch only shard-local streams
//! (the prerequisite for NUMA placement and multi-node operation: a shard
//! is self-contained and never reaches into the parent's allocations).
//!
//! Determinism contract (the same discipline as `threads ∈ {1,4,16}`,
//! DESIGN.md §2): sharding may change *who* computes, never *what*. Three
//! structural facts carry the proof:
//!
//! 1. **Shard boundaries are a pure function of the matrix.** They come
//!    from [`super::balanced_ranges`] on the CSR prefix sums — thread
//!    count never moves a row between shards.
//! 2. **Row-local state is decomposition-invariant.** Quantities indexed
//!    by row (`v̂_i`, `q̄_i`, `γ_i`) involve no cross-row reduction, so
//!    computing them per shard — in any order, on any thread — performs
//!    the exact same FP ops per row as the monolithic scan.
//! 3. **Order-sensitive reductions keep the legacy op order.** Sums that
//!    cross rows (the `α += γ·X[i,:]` scatter, the gap term `g̃`) are
//!    replayed sequentially in ascending shard order; because shards are
//!    contiguous ascending row ranges, that concatenation *is* the legacy
//!    ascending-row order, so the FP addition sequence is unchanged.
//!    Selection scores reduce through [`tree_reduce_scores`], which is
//!    exactly associative (comparisons don't round), so any partition
//!    yields the serial argmax bit for bit.
//!
//! The byte-traffic *model* stays anchored to the parent's canonical
//! streams (P-invariant by construction — see DESIGN.md §6.8); the
//! per-shard *physical* stream sizes, which may differ from the model when
//! a slab's qualifier decision diverges from the parent's, are exposed as
//! telemetry ([`ShardedDataset::physical_index_bytes`]).

use std::ops::Range;

use super::csc::CscMatrix;
use super::csr::CsrMatrix;
use super::{auto_threads, balanced_ranges, Dataset};

/// Coordinate vectors shorter than this are not worth a parallel argmax:
/// the scan is a few µs and thread spawn would dominate. Values are
/// identical either way (the tree reduction equals the serial scan), so
/// this is purely a performance gate.
pub const SELECT_PAR_MIN_D: usize = 1 << 16;

/// One row-range deferral from the fast solver's Phase A scan: row `row`'s
/// gradient moved by `gamma` at new margin `v_new`. Collected per shard in
/// ascending row order, then replayed sequentially (ascending shard order)
/// so the `α` scatter keeps the legacy FP op sequence.
#[derive(Clone, Copy, Debug)]
pub struct GammaEntry {
    pub row: u32,
    pub gamma: f64,
    pub v_new: f64,
}

/// One contiguous row slab of the parent dataset, self-contained: both
/// sparse views (with compact mirrors when the parent carries them) and
/// the slab's labels. The CSC view indexes rows *locally* (`0..len`);
/// `rows.start` maps them back to global row ids.
#[derive(Clone, Debug)]
pub struct Shard {
    /// Global row range `[start, end)` this shard owns.
    pub rows: Range<usize>,
    /// Row-major view of the slab: `rows.len() × n_cols`, global column
    /// ids (so its `α` scatters address the global gradient directly).
    pub csr: CsrMatrix,
    /// Column-major view of the slab with *local* row ids.
    pub csc: CscMatrix,
    /// Labels of the slab's rows.
    pub labels: Vec<f32>,
}

impl Shard {
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn nnz(&self) -> usize {
        self.csr.nnz()
    }

    /// Physical bytes of one full sweep of this shard's index streams
    /// (CSR + CSC) — telemetry, not the traffic model (see module docs).
    pub fn physical_index_bytes(&self) -> u64 {
        self.csr.index_bytes_total() + self.csc.index_bytes_total()
    }
}

/// A dataset partitioned into `P` contiguous nnz-balanced row shards.
/// Built once (O(nnz)) and cached in the solver workspace keyed by the
/// parent's identity token plus the requested shard count.
#[derive(Clone, Debug)]
pub struct ShardedDataset {
    shards: Vec<Shard>,
    requested: usize,
    n_rows: usize,
    n_cols: usize,
    nnz: usize,
    token: u64,
}

impl ShardedDataset {
    /// Partition `data` into at most `requested` shards. The effective
    /// count can be lower (never more shards than rows; degenerate
    /// matrices collapse to one) — [`ShardedDataset::n_shards`] reports
    /// what was actually built, and empty ranges are kept so the layout is
    /// exactly `balanced_ranges`' deterministic partition.
    pub fn build(data: &Dataset, requested: usize) -> Self {
        assert!(requested >= 1, "shard count must be >= 1");
        let csr = &data.csr;
        let row_ptr = csr.row_ptr();
        let cols_flat = csr.col_indices();
        let vals_flat = csr.values_flat();
        let compact_csr = data.csr.index_kind() == "u16-delta";
        let compact_csc = data.csc.index_kind() == "u16-delta";
        let shards = balanced_ranges(row_ptr, requested)
            .into_iter()
            .map(|r| {
                let base = row_ptr[r.start];
                let end = row_ptr[r.end];
                let indptr: Vec<usize> =
                    row_ptr[r.start..=r.end].iter().map(|&p| p - base).collect();
                let mut sub = CsrMatrix::from_parts(
                    r.len(),
                    csr.n_cols(),
                    indptr,
                    cols_flat[base..end].to_vec(),
                    vals_flat[base..end].to_vec(),
                );
                // Local-row transpose: the slab's columns list local rows
                // ascending, exactly the parent column's entries with
                // global row ∈ r (the counting sort preserves row order).
                let mut sub_t = CscMatrix::from_csr_threaded(&sub, auto_threads(sub.nnz()));
                // Follow the parent's substrate per view so a stripped
                // dataset stays u32 end to end. A slab the qualifier
                // rejects simply stays u32 — values are representation
                // -invariant (property-tested), and the traffic model is
                // charged off the parent streams either way.
                if compact_csr {
                    sub.build_compact();
                }
                if compact_csc {
                    sub_t.build_compact();
                }
                Shard {
                    labels: data.labels[r.start..r.end].to_vec(),
                    rows: r,
                    csr: sub,
                    csc: sub_t,
                }
            })
            .collect();
        Self {
            shards,
            requested,
            n_rows: data.n_rows(),
            n_cols: data.n_cols(),
            nnz: data.nnz(),
            token: data.token(),
        }
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Shards actually built (≤ requested; see [`ShardedDataset::build`]).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard count the caller asked for (recorded so bench rows can
    /// attribute results even when the partition clamped it).
    pub fn requested_shards(&self) -> usize {
        self.requested
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Does this partition describe `data` at `requested` shards? The
    /// workspace's single-slot shard cache key (token identity plus shape
    /// guards, mirroring `BootKey`).
    pub fn matches(&self, data: &Dataset, requested: usize) -> bool {
        self.token == data.token()
            && self.requested == requested
            && self.n_rows == data.n_rows()
            && self.n_cols == data.n_cols()
            && self.nnz == data.nnz()
    }

    /// Total physical index-stream bytes across all shards (telemetry;
    /// the CSR side equals the parent's exactly — per-row segments encode
    /// identically — while the CSC side may differ by boundary escapes).
    pub fn physical_index_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.physical_index_bytes()).sum()
    }
}

// ------------------------------------------------------------------------
// Selection plane: partial scores and the fixed-shape tree reduction
// ------------------------------------------------------------------------

/// The best selection score of one contiguous coordinate block:
/// `index` is the *global* coordinate id, `score = |α_index|`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScorePartial {
    pub index: usize,
    pub score: f64,
}

/// First-max-wins `|·|` argmax of one coordinate block starting at global
/// offset `offset` — the per-block leg of the parallel argmax. Replicates
/// `sampler::noisy_max::arg_abs_max` exactly (strict `>`, so the earliest
/// maximum wins; an all-NaN or empty block keeps the initial
/// `(offset, -∞)`, matching the serial scan's behaviour on that block).
pub fn block_abs_max(block: &[f64], offset: usize) -> ScorePartial {
    let mut best = ScorePartial { index: offset, score: f64::NEG_INFINITY };
    for (j, &a) in block.iter().enumerate() {
        let s = a.abs();
        if s > best.score {
            best = ScorePartial { index: offset + j, score: s };
        }
    }
    best
}

/// Deterministic fixed-shape pairwise tree reduction of block partials
/// into the global selection choice. The combine step keeps the right
/// partial only when its score *strictly* beats the left one; with
/// partials listed in ascending coordinate order this reproduces the
/// serial first-max-wins scan for **any** partition: max-with-earliest
/// -tie-break is exactly associative (score comparison never rounds), so
/// the reduction shape — and hence the shard count and thread count —
/// cannot change the result.
pub fn tree_reduce_scores(partials: &[ScorePartial]) -> ScorePartial {
    assert!(!partials.is_empty(), "tree reduction needs at least one partial");
    let mut level: Vec<ScorePartial> = partials.to_vec();
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        for pair in level.chunks(2) {
            next.push(if pair.len() == 2 && pair[1].score > pair[0].score {
                pair[1]
            } else {
                pair[0]
            });
        }
        level = next;
    }
    level[0]
}

/// Shard-parallel `argmax_j |α_j|`, bit-identical to
/// `sampler::noisy_max::arg_abs_max` at any `blocks`/`threads` (see
/// [`tree_reduce_scores`]). The serial fallback below [`SELECT_PAR_MIN_D`]
/// (or at one block / one thread) runs the identical per-block scan over
/// the whole vector, so the gate is purely a performance heuristic.
pub fn par_abs_argmax(alpha: &[f64], blocks: usize, threads: usize) -> usize {
    let n = alpha.len();
    let blocks = blocks.clamp(1, n.max(1));
    if threads <= 1 || blocks <= 1 || n < SELECT_PAR_MIN_D {
        return block_abs_max(alpha, 0).index;
    }
    let chunk = n.div_ceil(blocks);
    let partials: Vec<ScorePartial> = std::thread::scope(|s| {
        let handles: Vec<_> = alpha
            .chunks(chunk)
            .enumerate()
            .map(|(b, block)| s.spawn(move || block_abs_max(block, b * chunk)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("argmax block worker panicked"))
            .collect()
    });
    tree_reduce_scores(&partials).index
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::noisy_max::arg_abs_max;
    use crate::sparse::coo::CooBuilder;
    use crate::sparse::synth::SynthConfig;

    fn zipf_ds(seed: u64) -> Dataset {
        SynthConfig {
            name: "shard-unit".into(),
            n_rows: 240,
            n_cols: 300,
            avg_row_nnz: 7.0,
            zipf_exponent: 1.2,
            n_informative: 10,
            n_dense: 1,
            label_noise: 0.0,
            bias_col: true,
        }
        .generate(seed)
    }

    #[test]
    fn shards_cover_rows_and_nnz_exactly() {
        let ds = zipf_ds(3);
        for p in [1usize, 2, 3, 7, 16] {
            let sh = ShardedDataset::build(&ds, p);
            assert!(sh.n_shards() <= p);
            assert_eq!(sh.requested_shards(), p);
            let mut next = 0usize;
            let mut nnz = 0usize;
            for s in sh.shards() {
                assert_eq!(s.rows.start, next, "p={p}: shards must be contiguous");
                next = s.rows.end;
                nnz += s.nnz();
                assert_eq!(s.labels.len(), s.n_rows());
                assert_eq!(s.csr.n_cols(), ds.n_cols(), "columns stay global");
                assert_eq!(s.csc.n_rows(), s.n_rows(), "CSC rows are local");
            }
            assert_eq!(next, ds.n_rows(), "p={p}: shards must cover all rows");
            assert_eq!(nnz, ds.nnz(), "p={p}: shard nnz must sum to the parent");
        }
    }

    #[test]
    fn shard_rows_equal_parent_rows_verbatim() {
        let ds = zipf_ds(5);
        let sh = ShardedDataset::build(&ds, 5);
        for s in sh.shards() {
            for (local, global) in s.rows.clone().enumerate() {
                let (pi, pv) = ds.csr.row_raw(global);
                let (si, sv) = s.csr.row_raw(local);
                assert_eq!(pi, si, "row {global}: indices must match the parent");
                assert_eq!(pv, sv, "row {global}: values must match the parent");
                assert_eq!(s.labels[local], ds.labels[global]);
            }
        }
    }

    #[test]
    fn shard_columns_concatenate_to_parent_columns() {
        // Scanning shard p's column j (local rows, ascending) and mapping
        // back by rows.start, in ascending shard order, must visit exactly
        // the parent column j's (row, value) sequence — the fact Phase A
        // of the sharded fast solver rests on.
        let ds = zipf_ds(7);
        let sh = ShardedDataset::build(&ds, 4);
        for j in 0..ds.n_cols() {
            let parent: Vec<(usize, f32)> = ds.csc.col(j).collect();
            let mut stitched = Vec::with_capacity(parent.len());
            for s in sh.shards() {
                for (i_local, v) in s.csc.col(j) {
                    stitched.push((s.rows.start + i_local, v));
                }
            }
            assert_eq!(parent, stitched, "column {j} diverged");
        }
    }

    #[test]
    fn shard_csr_compact_bytes_sum_to_parent() {
        // The compact stream encodes each row segment independently
        // (first delta from 0), so a shard's CSR rows encode to exactly
        // the parent's words: physical CSR bytes are partition-invariant.
        let ds = zipf_ds(9);
        assert_eq!(ds.index_kind(), "u16-delta");
        for p in [1usize, 3, 16] {
            let sh = ShardedDataset::build(&ds, p);
            let total: u64 = sh.shards().iter().map(|s| s.csr.index_bytes_total()).sum();
            assert_eq!(total, ds.csr.index_bytes_total(), "p={p}");
        }
    }

    #[test]
    fn stripped_parent_yields_u32_shards() {
        let mut ds = zipf_ds(11);
        ds.strip_compact();
        let sh = ShardedDataset::build(&ds, 3);
        for s in sh.shards() {
            assert_eq!(s.csr.index_kind(), "u32");
            assert_eq!(s.csc.index_kind(), "u32");
        }
        let total: u64 = sh.shards().iter().map(|s| s.csr.index_bytes_total()).sum();
        assert_eq!(total, 4 * ds.nnz() as u64);
    }

    #[test]
    fn cache_key_matches_token_and_shape() {
        let ds = zipf_ds(13);
        let sh = ShardedDataset::build(&ds, 4);
        assert!(sh.matches(&ds, 4));
        assert!(!sh.matches(&ds, 5), "different requested count must miss");
        let other = zipf_ds(13); // same content, fresh token
        assert!(!sh.matches(&other, 4), "fresh construction must miss");
        assert!(sh.matches(&ds.clone(), 4), "clones share the token");
    }

    #[test]
    fn more_shards_than_rows_clamps_and_still_covers() {
        let mut b = CooBuilder::new(0, 5);
        for i in 0..3 {
            let r = b.add_row();
            b.push(r, i, 1.0 + i as f32);
        }
        let ds = Dataset::new(b.to_csr(), vec![1.0, 0.0, 1.0], "tiny");
        let sh = ShardedDataset::build(&ds, 16);
        assert!(sh.n_shards() <= 3, "cannot build more shards than rows");
        assert_eq!(sh.requested_shards(), 16);
        let covered: usize = sh.shards().iter().map(|s| s.n_rows()).sum();
        assert_eq!(covered, 3);
    }

    #[test]
    fn all_empty_row_slab_builds_empty_shard_views() {
        // rows 2..6 are empty: a middle shard can be all-empty rows
        let mut b = CooBuilder::new(0, 4);
        let r = b.add_row();
        b.push(r, 0, 1.0);
        let r = b.add_row();
        b.push(r, 1, 2.0);
        for _ in 0..4 {
            b.add_row(); // empty rows
        }
        let r = b.add_row();
        b.push(r, 3, 3.0);
        let ds = Dataset::new(b.to_csr(), vec![1.0; 7], "gaps");
        let sh = ShardedDataset::build(&ds, 3);
        let covered: usize = sh.shards().iter().map(|s| s.n_rows()).sum();
        assert_eq!(covered, 7);
        let nnz: usize = sh.shards().iter().map(|s| s.nnz()).sum();
        assert_eq!(nnz, 3);
    }

    #[test]
    fn tree_reduce_matches_serial_argmax_for_any_partition() {
        // adversarial score vectors: exact ties (first must win), zeros,
        // negatives, ±∞ magnitudes, NaN entries (never selected)
        let vectors: Vec<Vec<f64>> = vec![
            vec![0.0; 17],
            vec![1.0, -1.0, 1.0, 1.0],
            vec![-3.0, 2.0, 3.0, -3.0, 0.5],
            (0..101).map(|i| ((i * 37) % 23) as f64 - 11.0).collect(),
            vec![f64::NAN, 1.0, f64::NAN, 1.0],
            vec![f64::NAN, f64::NAN],
            vec![f64::INFINITY, f64::NEG_INFINITY, 5.0],
            vec![2.5],
        ];
        for alpha in &vectors {
            let want = arg_abs_max(alpha);
            for blocks in 1..=alpha.len() + 2 {
                let blocks = blocks.min(alpha.len().max(1));
                let chunk = alpha.len().div_ceil(blocks).max(1);
                let partials: Vec<ScorePartial> = alpha
                    .chunks(chunk)
                    .enumerate()
                    .map(|(b, blk)| block_abs_max(blk, b * chunk))
                    .collect();
                assert_eq!(
                    tree_reduce_scores(&partials).index,
                    want,
                    "alpha={alpha:?} blocks={blocks}"
                );
            }
        }
    }

    #[test]
    fn par_abs_argmax_bit_identical_above_and_below_gate() {
        // below the gate: serial fallback, trivially identical
        let small: Vec<f64> = (0..1000).map(|i| ((i * 31) % 97) as f64 - 48.0).collect();
        for (blocks, threads) in [(1usize, 1usize), (3, 4), (16, 2)] {
            assert_eq!(par_abs_argmax(&small, blocks, threads), arg_abs_max(&small));
        }
        // above the gate: genuinely parallel blocks, including exact ties
        // straddling block boundaries
        let n = SELECT_PAR_MIN_D + 17;
        let mut big: Vec<f64> = (0..n).map(|i| ((i * 131) % 1009) as f64 * 0.25).collect();
        big[100] = 1e6;
        big[n - 3] = 1e6; // exact tie: the earlier index must win
        let want = arg_abs_max(&big);
        assert_eq!(want, 100);
        for (blocks, threads) in [(2usize, 2usize), (3, 4), (16, 16), (64, 4)] {
            assert_eq!(
                par_abs_argmax(&big, blocks, threads),
                want,
                "blocks={blocks} threads={threads}"
            );
        }
    }
}
