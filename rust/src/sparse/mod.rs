//! Substrate: sparse linear algebra and dataset handling.
//!
//! The paper's algorithms need *both* orientations of the design matrix:
//! CSR rows for the `α += γ · X[i,:]` updates (average row sparsity `S_c`
//! nonzeros per row) and CSC columns for the "rows that use feature j" loop
//! (average column sparsity `S_r` nonzeros per column). [`Dataset`] bundles
//! the two views plus labels; [`synth`] generates paper-shaped synthetic
//! data; [`libsvm`] reads/writes the standard LIBSVM text format used by
//! the paper's real datasets (RCV1, News20, URL, Web, KDDA).

pub mod compact;
pub mod coo;
pub mod csc;
pub mod csr;
pub mod libsvm;
pub mod sharded;
pub mod synth;

use csc::CscMatrix;
use csr::CsrMatrix;

/// Minimum nnz before the block-parallel kernels (`matvec_par`,
/// `matvec_t_par`, `from_csr_threaded`) are worth their thread-spawn
/// overhead. The serial fallback is enforced *inside* those entry points
/// — callers may request any thread count without risking thread spawns
/// on tiny inputs. Outputs are bit-identical either way — the gate is
/// purely a performance heuristic.
pub const PAR_MIN_NNZ: usize = 1 << 15;

/// Default worker count for parallel substrate kernels: all available
/// cores for large inputs, serial below [`PAR_MIN_NNZ`].
pub fn auto_threads(nnz: usize) -> usize {
    if nnz < PAR_MIN_NNZ {
        1
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    }
}

/// Split the `0..n` items described by a CSR/CSC `indptr` (length `n+1`,
/// monotone prefix-nnz) into at most `blocks` contiguous ranges of
/// approximately equal nnz. Every range boundary is found by binary search
/// on the prefix sums, so the partition is deterministic in the matrix
/// alone — thread count never changes which block a column/row lands in,
/// only who computes it.
pub(crate) fn balanced_ranges(indptr: &[usize], blocks: usize) -> Vec<std::ops::Range<usize>> {
    let n = indptr.len() - 1;
    let blocks = blocks.max(1).min(n.max(1));
    let total = indptr[n];
    let mut ranges = Vec::with_capacity(blocks);
    let mut lo = 0usize;
    for b in 1..=blocks {
        let hi = if b == blocks {
            n
        } else {
            let target = total * b / blocks;
            indptr.partition_point(|&p| p < target).min(n).max(lo)
        };
        ranges.push(lo..hi);
        lo = hi;
    }
    ranges
}

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;

/// FNV-1a fold step over `bytes`, continuing from `h`. Stable across
/// processes — it feeds [`Dataset::fingerprint`], which is part of the
/// on-disk durability formats (DESIGN.md §6.11).
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// A binary-classification dataset: both sparse views of `X` plus labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Row-major view (for `X[i,:]` gathers and matvecs).
    pub csr: CsrMatrix,
    /// Column-major view (for the "rows using feature j" loop).
    pub csc: CscMatrix,
    /// Labels in {0.0, 1.0}, length `n_rows`.
    pub labels: Vec<f32>,
    /// Optional human-readable name (preset / file stem).
    pub name: String,
    /// Process-unique identity token assigned at construction and shared
    /// by clones (which alias the same immutable content). Keys the path
    /// engine's bootstrap cache (see rust/DESIGN.md §6.5); mutating a
    /// `Dataset`'s fields in place after construction is outside that
    /// cache's contract.
    token: u64,
    /// Stable content fingerprint (FNV-1a over dims, nonzeros, labels):
    /// the same bytes hash to the same value in every process, so this —
    /// not the process-local `token` — is what the durable ε ledger and
    /// checkpoint files key on (DESIGN.md §6.11). Two independently
    /// constructed datasets with identical content share a fingerprint,
    /// which is exactly right for privacy accounting: ε spends against
    /// the data, not against one process's handle to it.
    fingerprint: u64,
    /// Worker count the parallel CSC scatter actually used at
    /// construction (after [`csc::scatter_workers`]' gates and memory
    /// cap) — recorded so downstream reporting can attribute layout cost
    /// to the real worker count rather than the requested one.
    scatter_workers: usize,
}

/// Why a [`Dataset`] refused to construct (§6.11 input hardening). Typed
/// so ingestion layers — the LIBSVM reader, services accepting uploaded
/// data — can refuse one bad dataset without panicking the process.
#[derive(Clone, Debug, PartialEq)]
pub enum DatasetError {
    /// No rows or no columns: nothing to train on.
    Empty { rows: usize, cols: usize },
    /// `labels.len()` disagrees with the matrix's row count.
    LabelCountMismatch { rows: usize, labels: usize },
    /// A NaN/±Inf feature value at (row, col) — it would silently poison
    /// every dot product, gradient, and DP score downstream.
    NonFiniteValue { row: usize, col: usize },
    /// A label outside {0.0, 1.0} at `row` (the losses and the evaluators
    /// assume binary labels).
    BadLabel { row: usize, value: f32 },
}

impl std::fmt::Display for DatasetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DatasetError::Empty { rows, cols } => {
                write!(f, "empty dataset ({rows} rows x {cols} cols)")
            }
            DatasetError::LabelCountMismatch { rows, labels } => {
                write!(f, "label count {labels} != row count {rows}")
            }
            DatasetError::NonFiniteValue { row, col } => {
                write!(f, "non-finite feature value at ({row}, {col})")
            }
            DatasetError::BadLabel { row, value } => {
                write!(f, "label {value} at row {row} is not 0/1")
            }
        }
    }
}

impl std::error::Error for DatasetError {}

impl Dataset {
    /// [`Dataset::try_new`], panicking on invalid input — the right call
    /// for trusted in-process sources (the synthetic generators, `split`).
    /// Ingestion paths handling untrusted bytes should use `try_new` and
    /// refuse the one bad dataset instead.
    pub fn new(csr: CsrMatrix, labels: Vec<f32>, name: impl Into<String>) -> Self {
        Self::try_new(csr, labels, name).unwrap_or_else(|e| panic!("invalid dataset: {e}"))
    }

    /// Validate and construct: rejects empty matrices, label/row count
    /// mismatches, NaN/±Inf feature values, and non-binary labels with a
    /// typed [`DatasetError`] (§6.11). The `O(nnz)` finiteness sweep rides
    /// on construction, which is already `O(nnz)` for the transpose.
    pub fn try_new(
        mut csr: CsrMatrix,
        labels: Vec<f32>,
        name: impl Into<String>,
    ) -> Result<Self, DatasetError> {
        if csr.n_rows() == 0 || csr.n_cols() == 0 {
            return Err(DatasetError::Empty { rows: csr.n_rows(), cols: csr.n_cols() });
        }
        if csr.n_rows() != labels.len() {
            return Err(DatasetError::LabelCountMismatch {
                rows: csr.n_rows(),
                labels: labels.len(),
            });
        }
        // One O(nnz) sweep does double duty: the finiteness check and the
        // stable content fingerprint the durable ε ledger keys on. FNV-1a
        // over dims, then per row every (col, value bits) pair and the
        // row's nnz (so row boundaries are part of the stream), then the
        // label bits.
        let mut fp = fnv1a(FNV_OFFSET, &(csr.n_rows() as u64).to_le_bytes());
        fp = fnv1a(fp, &(csr.n_cols() as u64).to_le_bytes());
        for i in 0..csr.n_rows() {
            let mut row_nnz = 0u32;
            for (j, v) in csr.row(i) {
                if !v.is_finite() {
                    return Err(DatasetError::NonFiniteValue { row: i, col: j });
                }
                fp = fnv1a(fp, &(j as u32).to_le_bytes());
                fp = fnv1a(fp, &v.to_bits().to_le_bytes());
                row_nnz += 1;
            }
            fp = fnv1a(fp, &row_nnz.to_le_bytes());
        }
        if let Some(row) = labels.iter().position(|&y| y != 0.0 && y != 1.0) {
            return Err(DatasetError::BadLabel { row, value: labels[row] });
        }
        for &y in &labels {
            fp = fnv1a(fp, &y.to_bits().to_le_bytes());
        }
        // Block-parallel transpose for paper-scale matrices; the output is
        // bit-identical to the serial counting sort at any thread count
        // (the PAR_MIN_NNZ gate inside the entry point serializes tiny
        // inputs).
        let scatter_workers =
            csc::scatter_workers(auto_threads(csr.nnz()), csr.n_cols(), csr.nnz());
        let mut csc = CscMatrix::from_csr_threaded(&csr, auto_threads(csr.nnz()));
        // Compact u16-delta index mirrors for both views (DESIGN.md §6.6):
        // built once here so every hot loop downstream reads half-width
        // index streams. Matrices the qualifier rejects stay on u32.
        csr.build_compact();
        csc.build_compact();
        static NEXT_TOKEN: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);
        let token = NEXT_TOKEN.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(Self { csr, csc, labels, name: name.into(), token, fingerprint: fp, scatter_workers })
    }

    /// Worker count the parallel CSC scatter actually used when this
    /// dataset was built (1 when the serial fallback or the memory cap
    /// engaged). Clones share the value with the original.
    pub fn scatter_workers(&self) -> usize {
        self.scatter_workers
    }

    /// Drop the compact `u16-delta` index mirrors from both views,
    /// pinning the dataset to the plain `u32` substrate — the benchmark
    /// and property-test baseline ("how many bytes would this run have
    /// moved without compaction?"). Values and indices are untouched, so
    /// training output stays bit-identical; only the traffic accounting
    /// changes. Safe on clones: the compact stream never feeds the
    /// bootstrap cache, whose values are substrate-invariant.
    pub fn strip_compact(&mut self) {
        self.csr.clear_compact();
        self.csc.clear_compact();
    }

    /// The index substrate the hot loops read (`"u16-delta"` / `"u32"`).
    pub fn index_kind(&self) -> &'static str {
        self.csr.index_kind()
    }

    /// The dataset's process-local identity token (see the field docs).
    /// For anything that outlives the process — ledger records,
    /// checkpoint files — use [`Dataset::fingerprint`] instead.
    pub fn token(&self) -> u64 {
        self.token
    }

    /// The dataset's stable content fingerprint (see the field docs):
    /// identical content yields the same value across processes and
    /// restarts, so this is the durable spend/checkpoint key.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    pub fn n_rows(&self) -> usize {
        self.csr.n_rows()
    }

    pub fn n_cols(&self) -> usize {
        self.csr.n_cols()
    }

    pub fn nnz(&self) -> usize {
        self.csr.nnz()
    }

    /// Average number of nonzeros per row (the paper's `S_c`).
    pub fn avg_row_nnz(&self) -> f64 {
        self.nnz() as f64 / self.n_rows().max(1) as f64
    }

    /// Average number of nonzeros per column (the paper's `S_r`).
    pub fn avg_col_nnz(&self) -> f64 {
        self.nnz() as f64 / self.n_cols().max(1) as f64
    }

    /// Overall density in [0, 1].
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.n_rows() as f64 * self.n_cols() as f64).max(1.0)
    }

    /// Split into (train, test) by deterministic interleaving: every k-th
    /// row goes to test (k = 1/test_frac rounded). Deterministic so that
    /// experiments are exactly reproducible without an RNG.
    pub fn split(&self, test_frac: f64) -> (Dataset, Dataset) {
        assert!((0.0..1.0).contains(&test_frac));
        let k = (1.0 / test_frac.max(1e-9)).round().max(2.0) as usize;
        let mut train = coo::CooBuilder::new(0, self.n_cols());
        let mut test = coo::CooBuilder::new(0, self.n_cols());
        let mut ytr = Vec::new();
        let mut yte = Vec::new();
        for i in 0..self.n_rows() {
            let (dst, ys) = if i % k == k - 1 {
                (&mut test, &mut yte)
            } else {
                (&mut train, &mut ytr)
            };
            let row = dst.add_row();
            for (j, v) in self.csr.row(i) {
                dst.push(row, j, v);
            }
            ys.push(self.labels[i]);
        }
        (
            Dataset::new(train.to_csr(), ytr, format!("{}-train", self.name)),
            Dataset::new(test.to_csr(), yte, format!("{}-test", self.name)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        // X = [[1,0,2],[0,3,0],[0,0,4],[5,0,0]]
        let mut b = coo::CooBuilder::new(0, 3);
        let r0 = b.add_row();
        b.push(r0, 0, 1.0);
        b.push(r0, 2, 2.0);
        let r1 = b.add_row();
        b.push(r1, 1, 3.0);
        let r2 = b.add_row();
        b.push(r2, 2, 4.0);
        let r3 = b.add_row();
        b.push(r3, 0, 5.0);
        Dataset::new(b.to_csr(), vec![1.0, 0.0, 1.0, 0.0], "tiny")
    }

    #[test]
    fn stats() {
        let d = tiny();
        assert_eq!(d.n_rows(), 4);
        assert_eq!(d.n_cols(), 3);
        assert_eq!(d.nnz(), 5);
        assert!((d.avg_row_nnz() - 1.25).abs() < 1e-12);
        assert!((d.avg_col_nnz() - 5.0 / 3.0).abs() < 1e-12);
        assert!((d.density() - 5.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn csr_csc_agree() {
        let d = tiny();
        for i in 0..d.n_rows() {
            for (j, v) in d.csr.row(i) {
                let found = d.csc.col(j).any(|(r, cv)| r == i && cv == v);
                assert!(found, "({i},{j})={v} missing from CSC");
            }
        }
        assert_eq!(d.csr.nnz(), d.csc.nnz());
    }

    #[test]
    fn dataset_builds_compact_mirrors_and_strip_reverts() {
        let mut d = tiny();
        assert_eq!(d.index_kind(), "u16-delta", "small indices must qualify");
        assert_eq!(d.csc.index_kind(), "u16-delta");
        assert!(d.csr.index_bytes_total() < 4 * d.nnz() as u64);
        d.strip_compact();
        assert_eq!(d.index_kind(), "u32");
        assert_eq!(d.csr.index_bytes_total(), 4 * d.nnz() as u64);
    }

    #[test]
    fn tokens_unique_per_construction_shared_by_clones() {
        let a = tiny();
        let b = tiny();
        assert_ne!(a.token(), b.token(), "distinct constructions must differ");
        assert_eq!(a.token(), a.clone().token(), "clones alias the same data");
    }

    #[test]
    fn fingerprint_is_content_stable_and_content_sensitive() {
        // identical content → identical fingerprint, even across separate
        // constructions (the durable ledger key must not depend on which
        // process handle touched the data)
        let a = tiny();
        let b = tiny();
        assert_eq!(a.fingerprint(), b.fingerprint(), "same bytes, same key");
        assert_eq!(a.fingerprint(), a.clone().fingerprint());
        // any content change moves it: a value...
        let mut vb = coo::CooBuilder::new(0, 3);
        let r0 = vb.add_row();
        vb.push(r0, 0, 1.5); // tiny() has 1.0 here
        vb.push(r0, 2, 2.0);
        let r1 = vb.add_row();
        vb.push(r1, 1, 3.0);
        let r2 = vb.add_row();
        vb.push(r2, 2, 4.0);
        let r3 = vb.add_row();
        vb.push(r3, 0, 5.0);
        let changed_value =
            Dataset::new(vb.to_csr(), vec![1.0, 0.0, 1.0, 0.0], "tiny");
        assert_ne!(a.fingerprint(), changed_value.fingerprint());
        // ...and a label
        let mut lb = coo::CooBuilder::new(0, 3);
        let s0 = lb.add_row();
        lb.push(s0, 0, 1.0);
        lb.push(s0, 2, 2.0);
        let s1 = lb.add_row();
        lb.push(s1, 1, 3.0);
        let s2 = lb.add_row();
        lb.push(s2, 2, 4.0);
        let s3 = lb.add_row();
        lb.push(s3, 0, 5.0);
        let changed_label =
            Dataset::new(lb.to_csr(), vec![1.0, 1.0, 1.0, 0.0], "tiny");
        assert_ne!(a.fingerprint(), changed_label.fingerprint());
        // deterministic derived datasets agree too
        let (tr1, _) = a.split(0.25);
        let (tr2, _) = b.split(0.25);
        assert_eq!(tr1.fingerprint(), tr2.fingerprint());
        assert_ne!(tr1.fingerprint(), a.fingerprint());
    }

    #[test]
    fn try_new_rejects_bad_input_with_typed_errors() {
        // empty: no rows at all
        let empty = coo::CooBuilder::new(0, 3).to_csr();
        assert_eq!(
            Dataset::try_new(empty, vec![], "t").unwrap_err(),
            DatasetError::Empty { rows: 0, cols: 3 }
        );
        // label count disagrees with row count
        let mut b = coo::CooBuilder::new(0, 2);
        let r = b.add_row();
        b.push(r, 0, 1.0);
        assert_eq!(
            Dataset::try_new(b.to_csr(), vec![1.0, 0.0], "t").unwrap_err(),
            DatasetError::LabelCountMismatch { rows: 1, labels: 2 }
        );
        // NaN feature value, located by (row, col)
        let mut b = coo::CooBuilder::new(0, 2);
        let r = b.add_row();
        b.push(r, 1, f32::NAN);
        assert_eq!(
            Dataset::try_new(b.to_csr(), vec![1.0], "t").unwrap_err(),
            DatasetError::NonFiniteValue { row: 0, col: 1 }
        );
        // non-binary label
        let mut b = coo::CooBuilder::new(0, 2);
        let r = b.add_row();
        b.push(r, 0, 1.0);
        assert_eq!(
            Dataset::try_new(b.to_csr(), vec![2.0], "t").unwrap_err(),
            DatasetError::BadLabel { row: 0, value: 2.0 }
        );
    }

    #[test]
    #[should_panic(expected = "invalid dataset")]
    fn new_panics_on_invalid_input() {
        let mut b = coo::CooBuilder::new(0, 1);
        let r = b.add_row();
        b.push(r, 0, f32::INFINITY);
        Dataset::new(b.to_csr(), vec![1.0], "t");
    }

    #[test]
    fn split_partitions_rows() {
        let d = tiny();
        let (tr, te) = d.split(0.25);
        assert_eq!(tr.n_rows() + te.n_rows(), d.n_rows());
        assert_eq!(te.n_rows(), 1);
        assert_eq!(tr.n_cols(), d.n_cols());
    }

    #[test]
    fn balanced_ranges_partition_exactly() {
        // skewed prefix sums: most mass in the first items
        let indptr = vec![0usize, 100, 150, 160, 164, 166, 167, 167, 168];
        for blocks in 1..=10 {
            let ranges = balanced_ranges(&indptr, blocks);
            assert!(ranges.len() <= blocks.max(1));
            // contiguous, exhaustive cover of 0..n
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next);
                next = r.end;
            }
            assert_eq!(next, indptr.len() - 1);
        }
        // degenerate: empty item set
        let ranges = balanced_ranges(&[0usize], 4);
        assert_eq!(ranges, vec![0..0]);
    }
}
