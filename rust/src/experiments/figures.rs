//! Figure regeneration: the per-iteration series behind the paper's
//! Figures 1-4. Each function emits one CSV whose columns are exactly the
//! series plotted in the paper.

use anyhow::Result;

use super::{build_dataset, ExpConfig, EVAL_PRESETS};
use crate::fw::config::{FwConfig, SelectorKind};
use crate::fw::fast::FastFrankWolfe;
use crate::fw::standard::StandardFrankWolfe;
use crate::fw::trace::FwOutput;
use crate::textio::CsvTable;

fn nonprivate_pair(preset_idx: usize, cfg: &ExpConfig) -> (String, FwOutput, FwOutput) {
    let p = EVAL_PRESETS[preset_idx];
    let ds = build_dataset(p, cfg);
    let base = FwConfig {
        iters: cfg.iters,
        lambda: 50.0,
        trace_every: (cfg.iters / 100).max(1),
        ..Default::default()
    };
    let alg1 = StandardFrankWolfe::new(&ds, base.clone()).run();
    let alg2 = FastFrankWolfe::new(
        &ds,
        FwConfig { selector: SelectorKind::FibHeap, ..base },
    )
    .run();
    (p.name().to_string(), alg1, alg2)
}

/// **Figure 1** — convergence gap `g_t` vs iteration for Alg 1 (solid in
/// the paper) and Alg 2 + Alg 3 (dotted): the curves must overlap.
/// Columns: dataset, iter, gap_alg1, gap_alg2.
pub fn fig1_convergence(cfg: &ExpConfig) -> Result<CsvTable> {
    let mut t = CsvTable::new(["dataset", "iter", "gap_alg1", "gap_alg2"]);
    for idx in 0..EVAL_PRESETS.len() {
        let (name, a1, a2) = nonprivate_pair(idx, cfg);
        for (r1, r2) in a1.trace.iter().zip(&a2.trace) {
            t.push_row([
                name.clone(),
                r1.iter.to_string(),
                format!("{:.6e}", r1.gap),
                format!("{:.6e}", r2.gap),
            ]);
        }
    }
    t.write_file(cfg.out_dir.join("fig1_convergence.csv"))?;
    Ok(t)
}

/// **Figure 2** — how many times fewer FLOPs Alg 2 + Alg 3 needs than
/// Alg 1, as training progresses. Columns: dataset, iter, flops_ratio.
pub fn fig2_flops_ratio(cfg: &ExpConfig) -> Result<CsvTable> {
    let mut t = CsvTable::new(["dataset", "iter", "flops_alg1", "flops_alg2", "ratio"]);
    for idx in 0..EVAL_PRESETS.len() {
        let (name, a1, a2) = nonprivate_pair(idx, cfg);
        for (r1, r2) in a1.trace.iter().zip(&a2.trace) {
            let ratio = r1.flops as f64 / r2.flops.max(1) as f64;
            t.push_row([
                name.clone(),
                r1.iter.to_string(),
                r1.flops.to_string(),
                r2.flops.to_string(),
                format!("{ratio:.2}"),
            ]);
        }
    }
    t.write_file(cfg.out_dir.join("fig2_flops_ratio.csv"))?;
    Ok(t)
}

/// **Figure 3** (appendix) — cumulative Fibonacci-heap pops divided by
/// `‖w*‖₀`, per iteration: the paper's empirical validation that
/// `getNext` is `O(‖w*‖₀)` (the ratio stays ≤ ~3).
pub fn fig3_pops_ratio(cfg: &ExpConfig) -> Result<CsvTable> {
    let mut t =
        CsvTable::new(["dataset", "iter", "pops", "w_nnz_final", "pops_per_select", "ratio"]);
    for idx in 0..EVAL_PRESETS.len() {
        let (name, _a1, a2) = nonprivate_pair(idx, cfg);
        let nnz = a2.weights.nnz().max(1);
        for r in &a2.trace {
            // average pops per getNext so far, normalized by ‖w*‖₀ — the
            // paper's claim is this ratio stays ≤ ~3
            let per_select = r.pops as f64 / r.iter.max(1) as f64;
            t.push_row([
                name.clone(),
                r.iter.to_string(),
                r.pops.to_string(),
                nnz.to_string(),
                format!("{per_select:.3}"),
                format!("{:.4}", per_select / nnz as f64),
            ]);
        }
    }
    t.write_file(cfg.out_dir.join("fig3_pops_ratio.csv"))?;
    Ok(t)
}

/// **Figure 4** (appendix) — convergence gap vs cumulative FLOPs: Alg 2
/// reaches the same gap with orders of magnitude fewer operations.
pub fn fig4_gap_vs_flops(cfg: &ExpConfig) -> Result<CsvTable> {
    let mut t = CsvTable::new(["dataset", "algo", "flops", "gap"]);
    for idx in 0..EVAL_PRESETS.len() {
        let (name, a1, a2) = nonprivate_pair(idx, cfg);
        for r in &a1.trace {
            let gap = format!("{:.6e}", r.gap);
            t.push_row([name.clone(), "alg1".into(), r.flops.to_string(), gap]);
        }
        for r in &a2.trace {
            let gap = format!("{:.6e}", r.gap);
            t.push_row([name.clone(), "alg2".into(), r.flops.to_string(), gap]);
        }
    }
    t.write_file(cfg.out_dir.join("fig4_gap_vs_flops.csv"))?;
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExpConfig {
        let dir = std::env::temp_dir().join("dpfw_figs_test");
        ExpConfig { scale: 0.12, iters: 60, seed: 3, out_dir: dir, workers: 2 }
    }

    #[test]
    fn fig1_and_fig2_emit_all_presets() {
        let cfg = tiny_cfg();
        let t1 = fig1_convergence(&cfg).unwrap();
        assert!(t1.rows.len() >= 5);
        let datasets: std::collections::HashSet<_> =
            t1.rows.iter().map(|r| r[0].clone()).collect();
        assert_eq!(datasets.len(), 5);
        let t2 = fig2_flops_ratio(&cfg).unwrap();
        // final ratio must show Alg2 doing fewer FLOPs on pure-sparse
        // datasets; URL's dense informative block erases the non-private
        // advantage (exactly the paper's §4.2 observation), so only demand
        // parity there.
        for name in &datasets {
            let last = t2.rows.iter().rev().find(|r| &r[0] == name).unwrap();
            let ratio: f64 = last[4].parse().unwrap();
            if name == "url" {
                assert!(ratio > 0.5, "{name}: ratio {ratio}");
            } else {
                assert!(ratio > 1.0, "{name}: ratio {ratio}");
            }
        }
        assert!(cfg.out_dir.join("fig1_convergence.csv").exists());
    }
}
