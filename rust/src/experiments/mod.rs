//! Experiment harness: regenerates every table and figure in the paper's
//! evaluation (see DESIGN.md §5 for the experiment index).
//!
//! * Table 2 — dataset statistics ([`tables::datasets_table`])
//! * Figure 1 — convergence gap, Alg 1 vs Alg 2 ([`figures::fig1_convergence`])
//! * Figure 2 — FLOPs-reduction factor vs iteration ([`figures::fig2_flops_ratio`])
//! * Figure 3 — heap pops / ‖w*‖₀ ratio ([`figures::fig3_pops_ratio`])
//! * Figure 4 — gap vs cumulative FLOPs ([`figures::fig4_gap_vs_flops`])
//! * Table 3 — DP wall-clock speedups ([`tables::table3_speedup`])
//! * Table 4 — DP utility at ε=0.1 ([`tables::table4_utility`])
//! * §4.2 — URL ε-sweep ([`tables::eps_sweep`])
//! * Regularization path — per-λ utility over a K-point grid via the
//!   shared-bootstrap path engine ([`tables::lambda_path`]; beyond the
//!   paper, the standard consumption mode for LASSO-family solvers)
//!
//! Every entry point takes an [`ExpConfig`], writes a CSV under
//! `out_dir`, and returns the table for console display. Workloads are
//! the synthetic presets of [`crate::sparse::synth`] at per-preset scales
//! chosen so the full suite completes in minutes on a laptop while
//! preserving the paper's N ≪ D sparse regimes.

pub mod figures;
pub mod tables;

use std::path::PathBuf;
use std::sync::Arc;

use crate::sparse::synth::{DatasetPreset, SynthConfig};
use crate::sparse::Dataset;

/// Harness configuration (CLI-exposed knobs).
#[derive(Clone, Debug)]
pub struct ExpConfig {
    /// Global multiplier on the per-preset scales (1.0 = defaults below).
    pub scale: f64,
    /// Iteration budget T for the speed experiments.
    pub iters: usize,
    pub seed: u64,
    pub out_dir: PathBuf,
    /// Worker threads for grid experiments.
    pub workers: usize,
}

impl Default for ExpConfig {
    fn default() -> Self {
        Self {
            scale: 1.0,
            iters: 1000,
            seed: 42,
            out_dir: PathBuf::from("exp_out"),
            workers: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
        }
    }
}

impl ExpConfig {
    /// Quick settings for tests.
    pub fn quick(out_dir: impl Into<PathBuf>) -> Self {
        Self { scale: 0.25, iters: 120, seed: 7, out_dir: out_dir.into(), workers: 2 }
    }
}

/// Per-preset scale factors: each full-size preset (paper Table 2) is
/// shrunk so one DP training run takes O(seconds) while N ≪ D and the
/// sparsity statistics survive (see DESIGN.md §3 on why the *shape* of
/// Table 3 depends only on these statistics).
pub fn preset_exp_scale(p: DatasetPreset) -> f64 {
    match p {
        DatasetPreset::Rcv1 => 0.25,    // N≈5.1k, D≈11.8k
        DatasetPreset::News20 => 0.05,  // N≈1.0k, D≈67.8k
        DatasetPreset::Url => 0.004,    // N≈9.6k, D≈12.9k, dense block
        DatasetPreset::Web => 0.002,    // N≈0.7k, D≈33.2k, very long rows
        DatasetPreset::Kdda => 0.0015,  // N≈12.6k, D≈30.3k
    }
}

/// Build the scaled evaluation dataset for a preset.
pub fn build_dataset(p: DatasetPreset, cfg: &ExpConfig) -> Arc<Dataset> {
    let sc = preset_exp_scale(p) * cfg.scale;
    Arc::new(SynthConfig::preset(p).scale(sc).generate(cfg.seed ^ p.name().len() as u64))
}

/// The presets every experiment sweeps (paper order).
pub const EVAL_PRESETS: [DatasetPreset; 5] = [
    DatasetPreset::Rcv1,
    DatasetPreset::News20,
    DatasetPreset::Url,
    DatasetPreset::Web,
    DatasetPreset::Kdda,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_presets_stay_high_dimensional() {
        let cfg = ExpConfig { scale: 1.0, ..ExpConfig::quick("/tmp/x") };
        for p in EVAL_PRESETS {
            let ds = build_dataset(p, &cfg);
            assert!(ds.n_cols() >= 128, "{}: D={}", p.name(), ds.n_cols());
            assert!(ds.density() < 0.31, "{}: density {}", p.name(), ds.density());
        }
    }
}
