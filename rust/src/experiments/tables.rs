//! Table regeneration: the paper's Tables 2, 3, 4 and the §4.2 ε-sweep.

use std::sync::Arc;

use anyhow::Result;

use super::{build_dataset, ExpConfig, EVAL_PRESETS};
use crate::coordinator::{Algo, Coordinator, JobSpec, PathJob};
use crate::dp::accounting::PrivacyParams;
use crate::fw::config::{FwConfig, SelectorKind};
use crate::sparse::synth::DatasetPreset;
use crate::sparse::Dataset;
use crate::textio::CsvTable;

/// **Table 2** — dataset statistics (scaled presets + the full-size
/// numbers from the paper for reference).
pub fn datasets_table(cfg: &ExpConfig) -> Result<CsvTable> {
    let mut t = CsvTable::new([
        "dataset", "N", "D", "nnz", "S_c(avg row nnz)", "S_r(avg col nnz)",
        "density", "paper_N", "paper_D",
    ]);
    for p in EVAL_PRESETS {
        let full = crate::sparse::synth::SynthConfig::preset(p);
        let ds = build_dataset(p, cfg);
        t.push_row([
            p.name().to_string(),
            ds.n_rows().to_string(),
            ds.n_cols().to_string(),
            ds.nnz().to_string(),
            format!("{:.1}", ds.avg_row_nnz()),
            format!("{:.2}", ds.avg_col_nnz()),
            format!("{:.2e}", ds.density()),
            full.n_rows.to_string(),
            full.n_cols.to_string(),
        ]);
    }
    t.write_file(cfg.out_dir.join("table2_datasets.csv"))?;
    Ok(t)
}

/// One Table-3 grid cell spec.
fn dp_job(
    id: usize,
    label: String,
    data: Arc<Dataset>,
    algo: Algo,
    selector: SelectorKind,
    eps: f64,
    iters: usize,
    seed: u64,
) -> JobSpec {
    JobSpec {
        id,
        label,
        data,
        algo,
        cfg: FwConfig {
            iters,
            lambda: 50.0,
            privacy: Some(PrivacyParams::new(eps, 1e-6)),
            selector,
            seed,
            trace_every: 0,
            ..Default::default()
        },
        test_data: None,
    }
}

/// **Table 3** — wall-clock speedup of (Alg 2 + Alg 4) and of the
/// (Alg 2 + noisy-max) ablation over the standard DP Frank-Wolfe
/// (Alg 1 + noisy-max), at ε ∈ {1, 0.1}.
///
/// Columns mirror the paper: one row per dataset, speedups for each ε.
pub fn table3_speedup(cfg: &ExpConfig) -> Result<CsvTable> {
    let epsilons = [1.0, 0.1];
    let mut coord = Coordinator::new(cfg.workers);
    let mut jobs = Vec::new();
    let mut id = 0;
    for p in EVAL_PRESETS {
        let ds = build_dataset(p, cfg);
        for &eps in &epsilons {
            for (algo, sel, tag) in [
                (Algo::Standard, SelectorKind::NoisyMax, "alg1"),
                (Algo::Fast, SelectorKind::Bsls, "alg2+4"),
                (Algo::Fast, SelectorKind::NoisyMax, "alg2"),
            ] {
                jobs.push(dp_job(
                    id,
                    format!("{}|{}|{}", p.name(), eps, tag),
                    ds.clone(),
                    algo,
                    sel,
                    eps,
                    cfg.iters,
                    cfg.seed,
                ));
                id += 1;
            }
        }
    }
    let results = coord.run_all(jobs);
    let wall = |label: &str| -> f64 {
        results
            .iter()
            .filter_map(|r| r.as_ref().ok())
            .find(|r| r.label == label)
            .map(|r| r.output.wall_ms)
            .unwrap_or(f64::NAN)
    };
    let mut t = CsvTable::new([
        "dataset",
        "eps1_speedup_alg2+4",
        "eps1_speedup_alg2",
        "eps0.1_speedup_alg2+4",
        "eps0.1_speedup_alg2",
        "eps1_wall_alg1_ms",
        "eps0.1_wall_alg1_ms",
    ]);
    for p in EVAL_PRESETS {
        let n = p.name();
        let base1 = wall(&format!("{n}|1|alg1"));
        let base01 = wall(&format!("{n}|0.1|alg1"));
        t.push_row([
            n.to_string(),
            format!("{:.2}", base1 / wall(&format!("{n}|1|alg2+4"))),
            format!("{:.2}", base1 / wall(&format!("{n}|1|alg2"))),
            format!("{:.2}", base01 / wall(&format!("{n}|0.1|alg2+4"))),
            format!("{:.2}", base01 / wall(&format!("{n}|0.1|alg2"))),
            format!("{base1:.1}"),
            format!("{base01:.1}"),
        ]);
    }
    t.write_file(cfg.out_dir.join("table3_speedup.csv"))?;
    Ok(t)
}

/// **Table 4** — utility at strong privacy (ε = 0.1): accuracy, AUC and
/// solution sparsity of Alg 2 + Alg 4 with a large iteration budget
/// (paper: T = 400k, λ = 5000 — we scale T with the harness budget and
/// keep the λ↑, T↑ regime).
pub fn table4_utility(cfg: &ExpConfig) -> Result<CsvTable> {
    let mut coord = Coordinator::new(cfg.workers);
    let iters = cfg.iters * 10; // the paper's 100× is overkill at our scale
    let mut jobs = Vec::new();
    let mut splits = Vec::new();
    for (i, p) in EVAL_PRESETS.iter().enumerate() {
        let ds = build_dataset(*p, cfg);
        let (train, test) = ds.split(0.25);
        let train = Arc::new(train);
        let test = Arc::new(test);
        splits.push((p.name(), test.clone()));
        jobs.push(JobSpec {
            id: i,
            label: p.name().to_string(),
            data: train,
            algo: Algo::Fast,
            cfg: FwConfig {
                iters,
                lambda: 500.0,
                privacy: Some(PrivacyParams::new(0.1, 1e-6)),
                selector: SelectorKind::Bsls,
                seed: cfg.seed,
                trace_every: 0,
                ..Default::default()
            },
            test_data: Some(test),
        });
    }
    let results = coord.run_all(jobs);
    let mut t =
        CsvTable::new(["dataset", "accuracy_pct", "auc_pct", "sparsity_pct", "nnz", "iters"]);
    for r in results {
        let r = r.map_err(|e| anyhow::anyhow!("table4 job failed: {e}"))?;
        t.push_row([
            r.label.clone(),
            format!("{:.2}", r.accuracy.unwrap_or(f64::NAN)),
            format!("{:.2}", r.auc.unwrap_or(f64::NAN)),
            format!("{:.2}", r.sparsity_pct),
            r.output.weights.nnz().to_string(),
            r.output.iters_run.to_string(),
        ]);
    }
    t.write_file(cfg.out_dir.join("table4_utility.csv"))?;
    Ok(t)
}

/// The λ grid the regularization-path experiment sweeps (brackets the
/// paper's Table 3 λ = 50 and Table 4 λ↑ regimes).
pub const PATH_LAMBDAS: [f64; 7] = [5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0];

/// **Regularization path** — the path engine's consumption mode: one
/// [`PathJob`] per dataset (the K-point λ grid above, DP Alg 2 + Alg 4 at
/// ε = 1), dispatched *whole* to a single worker/workspace, so the dense
/// bootstrap `α = Xᵀq̄` — identical across λ — is computed once per
/// dataset instead of once per grid cell (DESIGN.md §6.5). Reports
/// utility, sparsity, per-λ wall time, and the bootstrap FLOPs actually
/// performed (zero for every warm λ).
pub fn lambda_path(cfg: &ExpConfig) -> Result<CsvTable> {
    let k = PATH_LAMBDAS.len();
    let mut coord = Coordinator::new(cfg.workers);
    for (i, p) in EVAL_PRESETS.iter().enumerate() {
        let ds = build_dataset(*p, cfg);
        let (train, test) = ds.split(0.25);
        coord.submit_path(PathJob {
            base_id: i * k,
            label: p.name().to_string(),
            data: Arc::new(train),
            algo: Algo::Fast,
            cfg: FwConfig {
                iters: cfg.iters,
                lambda: PATH_LAMBDAS[0], // per-λ values come from `lambdas`
                privacy: Some(PrivacyParams::new(1.0, 1e-6)),
                selector: SelectorKind::Bsls,
                seed: cfg.seed,
                trace_every: 0,
                ..Default::default()
            },
            lambdas: PATH_LAMBDAS.to_vec(),
            test_data: Some(Arc::new(test)),
        });
    }
    let results = coord.drain();
    let mut t = CsvTable::new([
        "dataset",
        "lambda",
        "accuracy_pct",
        "auc_pct",
        "sparsity_pct",
        "nnz",
        "wall_ms",
        "bootstrap_flops",
    ]);
    for (i, p) in EVAL_PRESETS.iter().enumerate() {
        for (j, &lam) in PATH_LAMBDAS.iter().enumerate() {
            let r = results[i * k + j]
                .as_ref()
                .map_err(|e| anyhow::anyhow!("lambda-path job failed: {e}"))?;
            t.push_row([
                p.name().to_string(),
                format!("{lam}"),
                format!("{:.2}", r.accuracy.unwrap_or(f64::NAN)),
                format!("{:.2}", r.auc.unwrap_or(f64::NAN)),
                format!("{:.2}", r.sparsity_pct),
                r.output.weights.nnz().to_string(),
                format!("{:.3}", r.output.wall_ms),
                r.output.bootstrap_flops.to_string(),
            ]);
        }
    }
    t.write_file(cfg.out_dir.join("lambda_path.csv"))?;
    Ok(t)
}

/// **§4.2** — the URL ε-sweep: speedup of Alg 2+4 over Alg 1 as ε varies.
/// The paper's explanation: at large ε the (slow, dense) informative
/// features are selected often; as ε shrinks, selection spreads to the
/// sparse tail and the per-iteration work drops.
pub fn eps_sweep(cfg: &ExpConfig) -> Result<CsvTable> {
    let ds = build_dataset(DatasetPreset::Url, cfg);
    let epsilons = [10.0, 3.0, 1.0, 0.3, 0.1];
    let mut coord = Coordinator::new(cfg.workers);
    let mut jobs = Vec::new();
    let mut id = 0;
    for &eps in &epsilons {
        for (algo, sel, tag) in [
            (Algo::Standard, SelectorKind::NoisyMax, "alg1"),
            (Algo::Fast, SelectorKind::Bsls, "alg2+4"),
        ] {
            jobs.push(dp_job(
                id,
                format!("{eps}|{tag}"),
                ds.clone(),
                algo,
                sel,
                eps,
                cfg.iters,
                cfg.seed,
            ));
            id += 1;
        }
    }
    let results = coord.run_all(jobs);
    let get = |label: &str| {
        results
            .iter()
            .filter_map(|r| r.as_ref().ok())
            .find(|r| r.label == label)
            .expect("missing cell")
    };
    let mut t = CsvTable::new(["epsilon", "wall_alg1_ms", "wall_alg2+4_ms", "speedup"]);
    for &eps in &epsilons {
        let a1 = get(&format!("{eps}|alg1"));
        let a24 = get(&format!("{eps}|alg2+4"));
        t.push_row([
            format!("{eps}"),
            format!("{:.1}", a1.output.wall_ms),
            format!("{:.1}", a24.output.wall_ms),
            format!("{:.2}", a1.output.wall_ms / a24.output.wall_ms),
        ]);
    }
    t.write_file(cfg.out_dir.join("eps_sweep_url.csv"))?;
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(name: &str) -> ExpConfig {
        ExpConfig {
            scale: 0.12,
            iters: 60,
            seed: 5,
            out_dir: std::env::temp_dir().join(name),
            workers: 4,
        }
    }

    #[test]
    fn table2_has_all_presets() {
        let cfg = tiny_cfg("dpfw_t2");
        let t = datasets_table(&cfg).unwrap();
        assert_eq!(t.rows.len(), 5);
        assert_eq!(t.rows[0][0], "rcv1");
    }

    #[test]
    fn table3_speedups_favor_fast_solver() {
        let cfg = tiny_cfg("dpfw_t3");
        let t = table3_speedup(&cfg).unwrap();
        assert_eq!(t.rows.len(), 5);
        // at even this tiny scale, Alg2+4 must beat Alg1 on the
        // highest-dimensional preset (news20)
        let news = t.rows.iter().find(|r| r[0] == "news20").unwrap();
        let sp: f64 = news[1].parse().unwrap();
        assert!(sp > 1.0, "news20 speedup {sp}");
    }

    #[test]
    fn lambda_path_reports_full_grid_with_one_bootstrap_each() {
        let cfg = ExpConfig { iters: 40, ..tiny_cfg("dpfw_lp") };
        let t = lambda_path(&cfg).unwrap();
        assert_eq!(t.rows.len(), 5 * PATH_LAMBDAS.len());
        // per dataset: first λ cold (bootstrap > 0), all others warm (0)
        for rows in t.rows.chunks(PATH_LAMBDAS.len()) {
            let boot: Vec<u64> = rows.iter().map(|r| r[7].parse().unwrap()).collect();
            assert!(boot[0] > 0, "{rows:?}");
            assert!(boot[1..].iter().all(|&b| b == 0), "{rows:?}");
        }
    }

    #[test]
    fn table4_reports_utility() {
        let cfg = ExpConfig { iters: 40, ..tiny_cfg("dpfw_t4") };
        let t = table4_utility(&cfg).unwrap();
        assert_eq!(t.rows.len(), 5);
        for row in &t.rows {
            let acc: f64 = row[1].parse().unwrap();
            assert!(acc > 20.0 && acc <= 100.0, "{row:?}");
        }
    }
}
