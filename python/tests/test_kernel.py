"""Pallas kernel (L1) vs pure-jnp oracle (ref.py) — the core correctness
signal for the compute hot-spot. Hypothesis sweeps shapes/seeds/block sizes;
assert_allclose against the reference on every draw."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import logistic_grad as kern
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _data(seed, n, d, density=1.0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    if density < 1.0:
        x *= (rng.random((n, d)) < density).astype(np.float32)
    w = (rng.standard_normal(d) * 0.3).astype(np.float32)
    y = (rng.random(n) < 0.5).astype(np.float32)
    m = np.ones(n, dtype=np.float32)
    return jnp.asarray(x), jnp.asarray(w), jnp.asarray(y), jnp.asarray(m)


# ---------------------------------------------------------------- alpha ----

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    blocks=st.integers(1, 4),
    block_n=st.sampled_from([8, 16, 32]),
    d=st.integers(3, 96),
)
def test_logistic_grad_matches_ref(seed, blocks, block_n, d):
    n = blocks * block_n
    x, w, y, m = _data(seed, n, d)
    got = kern.logistic_grad(x, w, y, m, block_n=block_n)
    want = ref.logistic_grad(x, w, y, m)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), density=st.floats(0.01, 0.5))
def test_logistic_grad_sparse_inputs(seed, density):
    x, w, y, m = _data(seed, 64, 128, density)
    got = kern.logistic_grad(x, w, y, m, block_n=16)
    want = ref.logistic_grad(x, w, y, m)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_zero_padded_rows_are_noops():
    """Zero rows of X must contribute nothing to alpha whatever y/m say —
    this is what lets the Rust runtime pad N up to the tile size."""
    x, w, y, m = _data(7, 32, 40)
    xp = jnp.concatenate([x, jnp.zeros((32, 40), jnp.float32)])
    yp = jnp.concatenate([y, jnp.ones(32, jnp.float32)])
    mp = jnp.concatenate([m, jnp.zeros(32, jnp.float32)])
    got = kern.logistic_grad(xp, w, yp, mp, block_n=16)
    want = ref.logistic_grad(x, w, y, m)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    # even with mask=1 on the padded rows, alpha is unchanged (x rows are 0)
    got2 = kern.logistic_grad(xp, w, yp, jnp.ones(64, jnp.float32), block_n=16)
    np.testing.assert_allclose(got2, want, rtol=2e-4, atol=2e-4)


def test_zero_padded_columns_are_noops():
    x, w, y, m = _data(11, 32, 24)
    xp = jnp.concatenate([x, jnp.zeros((32, 8), jnp.float32)], axis=1)
    wp = jnp.concatenate([w, jnp.zeros(8, jnp.float32)])
    got = kern.logistic_grad(xp, wp, y, m, block_n=16)
    want = ref.logistic_grad(x, w, y, m)
    np.testing.assert_allclose(got[:24], want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(got[24:], np.zeros(8), atol=1e-7)


def test_block_size_invariance():
    x, w, y, m = _data(3, 96, 50)
    outs = [kern.logistic_grad(x, w, y, m, block_n=b) for b in (8, 16, 32, 96)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-4, atol=2e-4)


def test_rejects_ragged_n():
    x, w, y, m = _data(0, 30, 8)
    with pytest.raises(ValueError):
        kern.logistic_grad(x, w, y, m, block_n=16)


# -------------------------------------------------------------- predict ----

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), blocks=st.integers(1, 3))
def test_predict_matches_ref(seed, blocks):
    n = blocks * 16
    x, w, _, _ = _data(seed, n, 33)
    got = kern.predict(x, w, block_n=16)
    want = ref.predict(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert bool(jnp.all((got >= 0) & (got <= 1)))
