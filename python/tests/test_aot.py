"""AOT exporter smoke tests: artifacts exist, are parseable HLO text, and
declare the right entry computation arity."""

import os
import re

import pytest

from compile import aot


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.export(out, n=32, d=16)
    return out


def test_all_artifacts_written(artifacts):
    names = {"alpha", "predict", "loss_gap", "fw_step"}
    files = set(os.listdir(artifacts))
    for n in names:
        assert f"{n}.hlo.txt" in files
    assert "manifest.txt" in files


def test_hlo_text_parses_shape(artifacts):
    text = open(os.path.join(artifacts, "alpha.hlo.txt")).read()
    assert "HloModule" in text
    # entry computation must take (X, w, y, m) = 4 parameters
    params = re.findall(r"parameter\(\d\)", text)
    assert len(set(params)) == 4
    # output is a tuple (return_tuple=True on the lowering path)
    assert "tuple(" in text or "ROOT" in text


def test_manifest_records_tile(artifacts):
    lines = open(os.path.join(artifacts, "manifest.txt")).read().splitlines()
    assert "n_tile=32" in lines
    assert "d_tile=16" in lines
    assert any(l.startswith("alpha.hlo.txt nargs=4") for l in lines)


def test_no_serialized_protos(artifacts):
    """Guard the 0.5.1 gotcha: we must ship text, not serialized protos."""
    for f in os.listdir(artifacts):
        p = os.path.join(artifacts, f)
        head = open(p, "rb").read(64)
        assert b"\x00" not in head, f"{f} looks binary"
