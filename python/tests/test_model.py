"""L2 model functions vs closed forms + FW-step semantics."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _data(seed, n=32, d=20):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    w = (rng.standard_normal(d) * 0.2).astype(np.float32)
    y = (rng.random(n) < 0.5).astype(np.float32)
    m = np.ones(n, dtype=np.float32)
    return jnp.asarray(x), jnp.asarray(w), jnp.asarray(y), jnp.asarray(m)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_alpha_dense_is_ref(seed):
    x, w, y, m = _data(seed)
    (got,) = model.alpha_dense(x, w, y, m)
    np.testing.assert_allclose(got, ref.logistic_grad(x, w, y, m),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_loss_gap(seed):
    x, w, y, m = _data(seed)
    lam = jnp.float32(5.0)
    loss, gap = model.loss_and_gap(x, w, y, m, lam)
    np.testing.assert_allclose(loss, ref.logloss_sum(x, w, y, m),
                               rtol=1e-5, atol=1e-5)
    alpha = ref.logistic_grad(x, w, y, m)
    np.testing.assert_allclose(gap, ref.fw_gap(alpha, w, lam),
                               rtol=2e-4, atol=2e-4)


def test_loss_matches_binary_cross_entropy():
    """softplus(v) - y v == -[y log p + (1-y) log(1-p)] for p = sigmoid(v)."""
    x, w, y, m = _data(5)
    loss = float(ref.logloss_sum(x, w, y, m))
    p = np.asarray(ref.predict(x, w), dtype=np.float64)
    yy = np.asarray(y, dtype=np.float64)
    bce = -np.sum(yy * np.log(p) + (1 - yy) * np.log1p(-p))
    assert abs(loss - bce) < 1e-3


def test_gap_nonnegative_on_l1_ball():
    """For w inside the L1 ball, the FW gap upper-bounds the suboptimality
    and is >= 0 whenever ||w||_1 <= lam."""
    for seed in range(10):
        x, w, y, m = _data(seed)
        w = w / max(1.0, float(jnp.sum(jnp.abs(w))))  # ||w||_1 <= 1
        lam = jnp.float32(1.0)
        _, gap = model.loss_and_gap(x, w, y, m, lam)
        assert float(gap) >= -1e-5


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fw_step_semantics(seed):
    x, w, y, m = _data(seed)
    lam, eta = jnp.float32(3.0), jnp.float32(0.5)
    w_next, j, gap = model.fw_dense_step(x, w, y, m, lam, eta)
    alpha = np.asarray(ref.logistic_grad(x, w, y, m))
    jj = int(np.argmax(np.abs(alpha)))
    assert int(j) == jj
    d = -np.asarray(w)
    d[jj] += -lam * np.sign(alpha[jj])
    np.testing.assert_allclose(w_next, np.asarray(w) + 0.5 * d,
                               rtol=2e-4, atol=2e-4)
    # step keeps the iterate in the lam-ball if it started there
    if np.abs(np.asarray(w)).sum() <= lam:
        assert float(jnp.sum(jnp.abs(w_next))) <= lam + 1e-4


def test_fw_converges_dense():
    """A few hundred dense FW steps must drive the gap down on a separable
    problem — sanity that the exported step function actually optimizes."""
    rng = np.random.default_rng(0)
    n, d = 64, 32
    x = rng.standard_normal((n, d)).astype(np.float32)
    truth = np.zeros(d, dtype=np.float32)
    truth[:4] = [3, -3, 2, -2]
    y = (1 / (1 + np.exp(-(x @ truth))) > 0.5).astype(np.float32)
    x, y = jnp.asarray(x), jnp.asarray(y)
    m = jnp.ones(n, jnp.float32)
    w = jnp.zeros(d, jnp.float32)
    lam = jnp.float32(8.0)
    gaps = []
    for t in range(200):
        eta = jnp.float32(2.0 / (t + 2.0))
        w, _, gap = model.fw_dense_step(x, w, y, m, lam, eta)
        gaps.append(float(gap))
    assert gaps[-1] < gaps[0] * 0.05
