"""Pure-jnp oracle for the Pallas kernels. No Pallas, no tiling tricks —
the straightest possible transcription of Algorithm 1 lines 4-6, used as the
correctness reference by pytest/hypothesis."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def logistic_grad(x, w, y, m):
    """alpha = X^T ((sigmoid(Xw) - y) * m)."""
    q = (jax.nn.sigmoid(x @ w) - y) * m
    return x.T @ q


def predict(x, w):
    """p = sigmoid(X w)."""
    return jax.nn.sigmoid(x @ w)


def logloss_sum(x, w, y, m):
    """Sum of logistic losses over unmasked rows.

    Uses the numerically stable form log(1+exp(v)) - y*v = softplus(v) - y*v.
    """
    v = x @ w
    return jnp.sum((jax.nn.softplus(v) - y * v) * m)


def fw_gap(alpha, w, lam):
    """Frank-Wolfe duality gap for the L1 ball of radius lam.

    g = -<alpha, d> with d = (-w + lam * sign(alpha_j) e_j) at
    j = argmax |alpha|, i.e. g = <alpha, w> + lam * max_j |alpha_j|.
    """
    return jnp.dot(alpha, w) + lam * jnp.max(jnp.abs(alpha))
