"""L1 Pallas kernel: the dense compute hot-spot of a Frank-Wolfe iteration.

Computes ``alpha = X^T @ (sigmoid(X @ w) - y) * m`` for a dense row-block
design matrix ``X`` of shape ``(N, D)``, weights ``w`` of shape ``(D,)``,
labels ``y`` in {0, 1} of shape ``(N,)`` and a row mask ``m`` of shape
``(N,)`` (1.0 for real rows, 0.0 for padding — zero-padded rows of ``X``
contribute nothing to ``alpha`` regardless of ``q``, but masking keeps the
loss/gap variants exact as well).

This is the paper's line 4-6 of Algorithm 1 (``v = Xw``; ``q = grad L(v)``;
``z = X^T q``) fused into a single pass. The paper runs this on a CPU where
the cache hierarchy does the blocking implicitly; on TPU we make the
HBM<->VMEM schedule explicit with a BlockSpec grid over row blocks:

  * grid = N // BLOCK_N steps; step ``i`` holds an ``(BLOCK_N, D)`` tile of
    ``X`` in VMEM plus the full ``w`` (D,) and the ``(BLOCK_N,)`` slices of
    ``y``/``m``;
  * the two matmuls (``x @ w`` and ``x.T @ q``) feed the MXU;
  * the output block index map is constant, so ``alpha`` lives in VMEM across
    the whole grid and is accumulated in-place — the standard Pallas
    reduction pattern, mirroring the paper's single linear pass over rows.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute the
Mosaic custom-call a real TPU lowering emits. Numerics are validated against
``ref.py`` by ``python/tests/``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row-block size. 128 rows x D columns of f32 must fit VMEM
# (~16 MiB/core on TPU): at D = 4096 a tile is 2 MiB, leaving room for
# double-buffering the next tile while the MXU chews on this one.
BLOCK_N = 128


def auto_block(n: int) -> int:
    """Largest usable row-block: BLOCK_N when it divides n, else n itself
    (small AOT tiles become a single grid step)."""
    return BLOCK_N if n % BLOCK_N == 0 else n


def _logistic_grad_kernel(x_ref, w_ref, y_ref, m_ref, o_ref):
    """One grid step: accumulate this row block's contribution to alpha."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                       # (BLOCK_N, D) tile in VMEM
    v = x @ w_ref[...]                   # (BLOCK_N,)  MXU matvec
    q = (jax.nn.sigmoid(v) - y_ref[...]) * m_ref[...]
    # Rank-1 reduction x^T q as a matmul so it also maps onto the MXU.
    o_ref[...] += q @ x                  # (D,)


@functools.partial(jax.jit, static_argnames=("block_n",))
def logistic_grad(x, w, y, m, *, block_n: int = BLOCK_N):
    """alpha = X^T ((sigmoid(Xw) - y) * m), Pallas-tiled over row blocks.

    ``x.shape[0]`` must be a multiple of ``block_n`` (the AOT exporter and
    the Rust runtime pad rows with zeros; zero rows are exact no-ops).
    """
    n, d = x.shape
    if n % block_n != 0:
        raise ValueError(f"N={n} must be a multiple of block_n={block_n}")
    grid = (n // block_n,)
    return pl.pallas_call(
        _logistic_grad_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(x, w, y, m)


def _predict_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jax.nn.sigmoid(x_ref[...] @ w_ref[...])


@functools.partial(jax.jit, static_argnames=("block_n",))
def predict(x, w, *, block_n: int = BLOCK_N):
    """p = sigmoid(X w), Pallas-tiled over row blocks (no cross-step state)."""
    n, d = x.shape
    if n % block_n != 0:
        raise ValueError(f"N={n} must be a multiple of block_n={block_n}")
    return pl.pallas_call(
        _predict_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        interpret=True,
    )(x, w)
