"""L2: the dense Frank-Wolfe step quantities as jitted jax functions.

These are the *dense oracle* for the sparse Rust solver (L3): the Rust side
implements Algorithm 2's incremental sparse updates; these functions compute
the same quantities from scratch, densely, through the Pallas kernel (L1),
and are AOT-lowered to HLO text by ``aot.py`` for the Rust PJRT runtime.

All functions take a row mask ``m`` so the Rust runtime can zero-pad N up to
the exported tile size: zero-padded rows of ``X`` contribute nothing to
``alpha`` and masked rows contribute nothing to the loss. Columns are padded
with zero columns, which produce zero ``alpha`` entries and never win the
argmax unless all real entries are zero too.

Python here is build-time only — nothing in this package is imported at
serving/training time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import logistic_grad as kern


def alpha_dense(x, w, y, m):
    """Full coordinate gradient alpha = X^T ((sigmoid(Xw) - y) * m).

    This is Algorithm 1 lines 4-7 (with ybar folded into q via the identity
    X^T sigma(Xw) - X^T y = X^T (sigma(Xw) - y)), computed by the L1 Pallas
    kernel.
    """
    return (kern.logistic_grad(x, w, y, m, block_n=kern.auto_block(x.shape[0])),)


def predict_dense(x, w):
    """p_i = sigmoid(x_i . w) — batch scoring for accuracy/AUC evaluation."""
    return (kern.predict(x, w, block_n=kern.auto_block(x.shape[0])),)


def loss_and_gap(x, w, y, m, lam):
    """(sum logistic loss over unmasked rows, FW duality gap on the L1 ball).

    The gap is g = <alpha, w> + lam * max_j |alpha_j| (see kernels/ref.py);
    the Rust side divides the loss by the true N.
    """
    v = x @ w
    loss = jnp.sum((jax.nn.softplus(v) - y * v) * m)
    alpha = kern.logistic_grad(x, w, y, m, block_n=kern.auto_block(x.shape[0]))
    gap = jnp.dot(alpha, w) + lam * jnp.max(jnp.abs(alpha))
    return (loss, gap)


def fw_dense_step(x, w, y, m, lam, eta):
    """One full *dense* Frank-Wolfe step, returning (w_next, j, gap).

    Used by tests/benches as a trajectory oracle for the non-private path:
    j = argmax |alpha|; d = -w + lam*sign(alpha_j) e_j; w' = w + eta*d.
    """
    alpha = kern.logistic_grad(x, w, y, m, block_n=kern.auto_block(x.shape[0]))
    j = jnp.argmax(jnp.abs(alpha))
    s = -lam * jnp.sign(alpha[j])
    d = -w + s * jax.nn.one_hot(j, w.shape[0], dtype=w.dtype)
    gap = jnp.dot(alpha, w) + lam * jnp.max(jnp.abs(alpha))
    return (w + eta * d, j.astype(jnp.int32), gap)
