"""AOT exporter: lower the L2 model functions to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text parser on
the Rust side (``HloModuleProto::from_text_file``) reassigns ids, so text
round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (all over f32, fixed oracle tile shapes N_TILE x D_TILE):

  alpha.hlo.txt      (X, w, y, m)        -> (alpha,)
  predict.hlo.txt    (X, w)              -> (p,)
  loss_gap.hlo.txt   (X, w, y, m, lam)   -> (loss_sum, gap)
  fw_step.hlo.txt    (X, w, y, m, lam, eta) -> (w_next, j, gap)

The Rust runtime zero-pads real data up to the tile shape (zero rows/columns
are exact no-ops for every exported function; the mask handles the loss) and
accumulates ``alpha``/``loss`` over row tiles when N > N_TILE.

Usage: python -m compile.aot --out ../artifacts [--n 256] [--d 512]
Run from ``python/`` (the Makefile does). A manifest line per artifact is
written to ``<out>/manifest.txt`` so the Rust side can sanity-check shapes.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Default oracle tile. Small enough that interpret-mode Pallas lowering and
# XLA-CPU compilation stay fast; large enough to exercise real workloads
# (the Rust oracle tiles N and requires D <= D_TILE).
N_TILE = 256
D_TILE = 512


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(out_dir: str, n: int = N_TILE, d: int = D_TILE) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    f32 = jnp.float32
    X = jax.ShapeDtypeStruct((n, d), f32)
    w = jax.ShapeDtypeStruct((d,), f32)
    y = jax.ShapeDtypeStruct((n,), f32)
    m = jax.ShapeDtypeStruct((n,), f32)
    scalar = jax.ShapeDtypeStruct((), f32)

    specs = [
        ("alpha", model.alpha_dense, (X, w, y, m)),
        ("predict", model.predict_dense, (X, w)),
        ("loss_gap", model.loss_and_gap, (X, w, y, m, scalar)),
        ("fw_step", model.fw_dense_step, (X, w, y, m, scalar, scalar)),
    ]

    manifest = [f"n_tile={n}", f"d_tile={d}"]
    written = []
    for name, fn, args in specs:
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name}.hlo.txt nargs={len(args)}")
        written.append(path)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--n", type=int, default=N_TILE)
    ap.add_argument("--d", type=int, default=D_TILE)
    args = ap.parse_args()
    export(args.out, args.n, args.d)


if __name__ == "__main__":
    main()
